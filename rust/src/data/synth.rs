//! Synthetic embedding generators.
//!
//! The paper's datasets (gist-960-1M, rqa-768-10M, ...) are large
//! downloads/proprietary; these generators reproduce the property that
//! drives every LeanVec result: the *spectral shape* of the database and
//! query second moments, and their mismatch in the OOD case.
//!
//! Database: `x = U diag(s) z`, `z ~ N(0, I)`, `U` random orthogonal,
//! `s_j = (1 + j)^-decay` (power-law spectrum like real deep-learning
//! embeddings). ID queries repeat the process with fresh samples. OOD
//! queries re-weight the spectrum toward the database's *tail*
//! directions and mix in a rotated basis — modeling text-vs-image
//! encoders (t2i/wit/laion) and question-vs-answer encoders (rqa), whose
//! second moments disagree exactly this way.

use crate::config::Similarity;
use crate::linalg::matrix::normalize;
use crate::linalg::qr::random_orthonormal;
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// How queries relate to the database distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryDist {
    /// identical generative process (fresh samples)
    InDistribution,
    /// OOD with the given strength in [0, 1]: 0 = ID, 1 = fully
    /// tail-concentrated + rotated
    OutOfDistribution(f32),
}

/// Generator specification.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub dim: usize,
    pub n: usize,
    pub n_learn_queries: usize,
    pub n_test_queries: usize,
    pub similarity: Similarity,
    pub queries: QueryDist,
    /// power-law spectrum exponent
    pub decay: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// In-distribution dataset shorthand.
    pub fn id(name: &str, dim: usize, n: usize, n_queries: usize) -> SynthSpec {
        SynthSpec {
            name: name.to_string(),
            dim,
            n,
            n_learn_queries: n_queries,
            n_test_queries: n_queries,
            similarity: Similarity::L2,
            queries: QueryDist::InDistribution,
            decay: 0.6,
            seed: 0xDA7A,
        }
    }

    /// Out-of-distribution dataset shorthand (inner product, the
    /// cross-modal default).
    pub fn ood(name: &str, dim: usize, n: usize, n_queries: usize) -> SynthSpec {
        SynthSpec {
            name: name.to_string(),
            dim,
            n,
            n_learn_queries: n_queries,
            n_test_queries: n_queries,
            similarity: Similarity::InnerProduct,
            queries: QueryDist::OutOfDistribution(0.7),
            decay: 0.6,
            seed: 0xDA7A,
        }
    }
}

/// A generated dataset with disjoint learn/test query splits
/// (the paper's protocol: learn for LeanVec-OOD + calibration, test for
/// reported numbers).
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    pub similarity: Similarity,
    pub database: Vec<Vec<f32>>,
    pub learn_queries: Vec<Vec<f32>>,
    pub test_queries: Vec<Vec<f32>>,
}

fn sample_rows(
    n: usize,
    basis: &Matrix,
    spectrum: &[f32],
    rng: &mut Rng,
    normalize_rows: bool,
) -> Vec<Vec<f32>> {
    let dd = basis.rows;
    (0..n)
        .map(|_| {
            // v = U^T (s .* z): basis rows are the directions
            let mut v = vec![0.0f32; dd];
            for (j, &s) in spectrum.iter().enumerate() {
                let c = s * rng.gaussian_f32();
                if c.abs() < 1e-12 {
                    continue;
                }
                let dir = basis.row(j);
                for (x, &b) in v.iter_mut().zip(dir.iter()) {
                    *x += c * b;
                }
            }
            if normalize_rows {
                normalize(&mut v);
            }
            v
        })
        .collect()
}

/// Generate a dataset from a spec.
pub fn generate(spec: &SynthSpec) -> Dataset {
    let mut rng = Rng::new(spec.seed ^ spec.dim as u64 ^ (spec.n as u64).rotate_left(17));
    let dd = spec.dim;
    let basis = random_orthonormal(dd, dd, &mut rng); // rows = directions
    let spectrum: Vec<f32> = (0..dd)
        .map(|j| (1.0 + j as f64).powf(-spec.decay) as f32)
        .collect();
    let norm_rows = spec.similarity == Similarity::Cosine;

    let database = sample_rows(spec.n, &basis, &spectrum, &mut rng, norm_rows);

    let (q_basis, q_spectrum) = match spec.queries {
        QueryDist::InDistribution => (basis.clone(), spectrum.clone()),
        QueryDist::OutOfDistribution(strength) => {
            // tail-concentrated spectrum: queries put energy where the
            // database has little (what breaks database-only PCA)
            let mut rev = spectrum.clone();
            rev.reverse();
            let q_spec: Vec<f32> = spectrum
                .iter()
                .zip(rev.iter())
                .map(|(&s, &r)| (1.0 - strength) * s + strength * r)
                .collect();
            // partially rotated basis (different encoder)
            let g = random_orthonormal(dd, dd, &mut rng);
            let mut mixed = basis.clone();
            mixed.lerp(&g, 1.0 - 0.5 * strength, 0.5 * strength);
            // re-orthonormalize the mixture
            let q_basis = crate::linalg::qr::qr_orthonormal_columns(&mixed.transpose())
                .transpose();
            (q_basis, q_spec)
        }
    };

    let learn_queries = sample_rows(
        spec.n_learn_queries,
        &q_basis,
        &q_spectrum,
        &mut rng,
        norm_rows,
    );
    let test_queries = sample_rows(
        spec.n_test_queries,
        &q_basis,
        &q_spectrum,
        &mut rng,
        norm_rows,
    );

    Dataset {
        name: spec.name.clone(),
        dim: dd,
        similarity: spec.similarity,
        database,
        learn_queries,
        test_queries,
    }
}

/// The Table-1 roster scaled to this testbed (`scale` multiplies the
/// database sizes; 1.0 -> 20k vectors per dataset, queries 500+500).
pub fn paper_datasets(scale: f64) -> Vec<SynthSpec> {
    let n = |base: usize| ((base as f64 * scale) as usize).max(500);
    let q = 500usize;
    let mk = |name: &str,
              dim: usize,
              sim: Similarity,
              queries: QueryDist,
              nn: usize| SynthSpec {
        name: name.to_string(),
        dim,
        n: nn,
        n_learn_queries: q,
        n_test_queries: q,
        similarity: sim,
        queries,
        decay: 0.6,
        seed: 0xDA7A ^ dim as u64,
    };
    vec![
        // ID (Table 1, top)
        mk("gist-960", 960, Similarity::L2, QueryDist::InDistribution, n(20_000)),
        mk("deep-256", 256, Similarity::L2, QueryDist::InDistribution, n(20_000)),
        mk(
            "open-images-512",
            512,
            Similarity::Cosine,
            QueryDist::InDistribution,
            n(20_000),
        ),
        // OOD (Table 1, bottom)
        mk(
            "t2i-200",
            200,
            Similarity::InnerProduct,
            QueryDist::OutOfDistribution(0.5),
            n(20_000),
        ),
        mk(
            "wit-512",
            512,
            Similarity::InnerProduct,
            QueryDist::OutOfDistribution(0.7),
            n(20_000),
        ),
        mk(
            "laion-512",
            512,
            Similarity::InnerProduct,
            QueryDist::OutOfDistribution(0.9),
            n(20_000),
        ),
        mk(
            "rqa-768",
            768,
            Similarity::InnerProduct,
            QueryDist::OutOfDistribution(0.7),
            n(20_000),
        ),
    ]
}

/// Paper Table-1 target dimensionality per dataset (d column).
pub fn paper_target_dim(name: &str) -> usize {
    match name {
        "gist-960" => 160,
        "deep-256" => 96,
        "open-images-512" => 160,
        "t2i-200" => 192,
        "wit-512" => 256,
        "laion-512" => 320,
        "rqa-768" => 160,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::leanvec::model::rows_to_matrix;

    fn small_spec(queries: QueryDist) -> SynthSpec {
        SynthSpec {
            name: "test".into(),
            dim: 32,
            n: 400,
            n_learn_queries: 200,
            n_test_queries: 100,
            similarity: Similarity::InnerProduct,
            queries,
            decay: 0.8,
            seed: 7,
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn shapes_and_splits() {
        let ds = generate(&small_spec(QueryDist::InDistribution));
        assert_eq!(ds.database.len(), 400);
        assert_eq!(ds.learn_queries.len(), 200);
        assert_eq!(ds.test_queries.len(), 100);
        assert!(ds.database.iter().all(|r| r.len() == 32));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn deterministic_given_seed() {
        let a = generate(&small_spec(QueryDist::InDistribution));
        let b = generate(&small_spec(QueryDist::InDistribution));
        assert_eq!(a.database[17], b.database[17]);
        assert_eq!(a.test_queries[3], b.test_queries[3]);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn database_spectrum_decays() {
        let ds = generate(&small_spec(QueryDist::InDistribution));
        let kx = rows_to_matrix(&ds.database).second_moment();
        let (w, _) = crate::linalg::eigen::eigh(&kx);
        assert!(w[0] > 5.0 * w[16], "top {} vs mid {}", w[0], w[16]);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn ood_moments_mismatch_id_moments_match() {
        let id = generate(&small_spec(QueryDist::InDistribution));
        let ood = generate(&small_spec(QueryDist::OutOfDistribution(0.9)));
        let mismatch = |ds: &Dataset| {
            let kx = rows_to_matrix(&ds.database).second_moment();
            let kq = rows_to_matrix(&ds.learn_queries).second_moment();
            let mut diff = kx.clone();
            diff.lerp(&kq, 1.0, -1.0);
            (diff.frobenius_norm() / kx.frobenius_norm()) as f64
        };
        let m_id = mismatch(&id);
        let m_ood = mismatch(&ood);
        assert!(m_ood > 2.0 * m_id, "ood {m_ood} vs id {m_id}");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn cosine_datasets_are_normalized() {
        let mut spec = small_spec(QueryDist::InDistribution);
        spec.similarity = Similarity::Cosine;
        let ds = generate(&spec);
        for r in ds.database.iter().take(10) {
            let n: f32 = r.iter().map(|x| x * x).sum();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn roster_matches_table1_signature() {
        let specs = paper_datasets(0.05);
        assert_eq!(specs.len(), 7);
        let by_name: std::collections::HashMap<_, _> =
            specs.iter().map(|s| (s.name.clone(), s)).collect();
        assert_eq!(by_name["gist-960"].dim, 960);
        assert_eq!(by_name["gist-960"].similarity, Similarity::L2);
        assert_eq!(by_name["rqa-768"].dim, 768);
        assert!(matches!(
            by_name["rqa-768"].queries,
            QueryDist::OutOfDistribution(_)
        ));
        assert_eq!(by_name["open-images-512"].similarity, Similarity::Cosine);
        assert!(paper_target_dim("gist-960") == 160);
    }
}
