//! Exact ground truth + recall metrics (Appendix D.3: k-recall@k).

use crate::config::Similarity;
use crate::index::flat::FlatIndex;
use crate::util::threadpool::parallel_map;

/// Exact top-k ids for every query (brute force over the database).
pub fn ground_truth(
    database: &[Vec<f32>],
    queries: &[Vec<f32>],
    k: usize,
    sim: Similarity,
) -> Vec<Vec<u32>> {
    // cosine == IP on normalized data; FlatIndex scores raw IP, so
    // normalize database copies when needed
    let flat = match sim {
        Similarity::Cosine => {
            let normed: Vec<Vec<f32>> = database
                .iter()
                .map(|r| {
                    let mut v = r.clone();
                    crate::linalg::matrix::normalize(&mut v);
                    v
                })
                .collect();
            FlatIndex::new(&normed, Similarity::InnerProduct)
        }
        s => FlatIndex::new(database, s),
    };
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    parallel_map(queries.len(), threads, |i| flat.search(&queries[i], k).0)
}

/// `|got ∩ truth| / k` averaged over queries (k-recall@k).
pub fn recall_at_k(got: &[Vec<u32>], truth: &[Vec<u32>], k: usize) -> f64 {
    assert_eq!(got.len(), truth.len());
    if got.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    for (g, t) in got.iter().zip(truth.iter()) {
        let tk = &t[..k.min(t.len())];
        hits += g.iter().take(k).filter(|id| tk.contains(id)).count();
    }
    hits as f64 / (k * got.len()) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn self_queries_have_perfect_recall_l2() {
        let db = rows(100, 8, 1);
        let gt = ground_truth(&db, &db[..10].to_vec(), 1, Similarity::L2);
        for (i, t) in gt.iter().enumerate() {
            assert_eq!(t[0], i as u32);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_metric_boundaries() {
        let truth = vec![vec![0u32, 1, 2], vec![3, 4, 5]];
        assert_eq!(recall_at_k(&truth, &truth, 3), 1.0);
        let miss = vec![vec![9u32, 10, 11], vec![12, 13, 14]];
        assert_eq!(recall_at_k(&miss, &truth, 3), 0.0);
        let half = vec![vec![0u32, 10, 11], vec![3, 13, 14]];
        assert!((recall_at_k(&half, &truth, 3) - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn cosine_gt_ignores_scale() {
        let mut db = rows(50, 8, 2);
        // duplicate vector 0 scaled by 100 at slot 1
        db[1] = db[0].iter().map(|&x| x * 100.0).collect();
        let q = vec![db[0].clone()];
        let gt = ground_truth(&db, &q, 2, Similarity::Cosine);
        // both the original and the scaled copy are perfect cosine matches
        assert!(gt[0].contains(&0) && gt[0].contains(&1));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn recall_with_k_smaller_than_lists() {
        let truth = vec![vec![0u32, 1, 2, 3, 4]];
        let got = vec![vec![0u32, 9, 9, 9, 9]];
        assert_eq!(recall_at_k(&got, &truth, 1), 1.0);
    }
}
