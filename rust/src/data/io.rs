//! Vector-file I/O: fvecs/ivecs (the TexMex/ANN-benchmarks formats) and
//! a minimal npy (v1.0, C-order f32) reader/writer for interchange with
//! the Python side.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Write fvecs: per vector, a little-endian u32 dim then dim f32s.
pub fn write_fvecs(path: &Path, rows: &[Vec<f32>]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for r in rows {
        w.write_all(&(r.len() as u32).to_le_bytes())?;
        for &v in r {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read fvecs.
pub fn read_fvecs(path: &Path) -> std::io::Result<Vec<Vec<f32>>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut rows = Vec::new();
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = u32::from_le_bytes(dim_buf) as usize;
        let mut buf = vec![0u8; dim * 4];
        r.read_exact(&mut buf)?;
        rows.push(
            buf.chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok(rows)
}

/// Write ivecs (u32 payloads, same framing as fvecs).
pub fn write_ivecs(path: &Path, rows: &[Vec<u32>]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for r in rows {
        w.write_all(&(r.len() as u32).to_le_bytes())?;
        for &v in r {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read ivecs.
pub fn read_ivecs(path: &Path) -> std::io::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut rows = Vec::new();
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = u32::from_le_bytes(dim_buf) as usize;
        let mut buf = vec![0u8; dim * 4];
        r.read_exact(&mut buf)?;
        rows.push(
            buf.chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok(rows)
}

/// Write a 2-D f32 array as npy v1.0 (little-endian, C order).
pub fn write_npy_f32(path: &Path, rows: usize, cols: usize, data: &[f32]) -> std::io::Result<()> {
    assert_eq!(data.len(), rows * cols);
    let mut w = BufWriter::new(File::create(path)?);
    let header_body = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({rows}, {cols}), }}"
    );
    // pad so that 10 + len(header) is a multiple of 64, newline-terminated
    let unpadded = 10 + header_body.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    let header = format!("{header_body}{}\n", " ".repeat(pad));
    w.write_all(b"\x93NUMPY\x01\x00")?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read a 2-D f32 npy (v1.x, little-endian, C order only).
pub fn read_npy_f32(path: &Path) -> std::io::Result<(usize, usize, Vec<f32>)> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "not an npy file",
        ));
    }
    let mut len_buf = [0u8; 2];
    r.read_exact(&mut len_buf)?;
    let hlen = u16::from_le_bytes(len_buf) as usize;
    let mut header = vec![0u8; hlen];
    r.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") || header.contains("'fortran_order': True") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "only little-endian C-order f32 npy supported",
        ));
    }
    // parse "(rows, cols)"
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bad npy shape")
        })?;
    let dims: Vec<usize> = shape_part
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let (rows, cols) = match dims.len() {
        1 => (dims[0], 1),
        2 => (dims[0], dims[1]),
        _ => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "only 1-D/2-D npy supported",
            ))
        }
    };
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    if buf.len() < rows * cols * 4 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "npy payload truncated",
        ));
    }
    let data = buf[..rows * cols * 4]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok((rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("leanvec-io-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0, 3.0], vec![-4.5, 0.0, 9.25]];
        let p = tmp("a.fvecs");
        write_fvecs(&p, &rows).unwrap();
        assert_eq!(read_fvecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1u32, 2, 3], vec![7, 8]];
        let p = tmp("b.ivecs");
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let p = tmp("c.npy");
        write_npy_f32(&p, 3, 4, &data).unwrap();
        let (r, c, d) = read_npy_f32(&p).unwrap();
        assert_eq!((r, c), (3, 4));
        assert_eq!(d, data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_rejects_garbage() {
        let p = tmp("d.npy");
        std::fs::write(&p, b"not-an-npy").unwrap();
        assert!(read_npy_f32(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_fvecs_reads_empty() {
        let p = tmp("e.fvecs");
        std::fs::write(&p, b"").unwrap();
        assert!(read_fvecs(&p).unwrap().is_empty());
        std::fs::remove_file(&p).ok();
    }
}
