//! Vector-file I/O: fvecs/ivecs (the TexMex/ANN-benchmarks formats), a
//! minimal npy (v1.0, C-order f32) reader/writer for interchange with
//! the Python side, and the little-endian binary primitives ([`bin`],
//! [`crc32`]) shared by every section of the index snapshot format
//! (see `docs/SNAPSHOT_FORMAT.md` and `crate::index::persist`).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// 256-entry lookup table for [`crc32`], built at compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
};

/// CRC-32 (IEEE 802.3, the zlib/PNG polynomial), used as the
/// per-section checksum of the snapshot format. Table-driven: store
/// sections are hundreds of MB at production scale and this runs on
/// every serve-side snapshot load.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc = (crc >> 8) ^ CRC32_TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Little-endian binary encode/decode helpers for snapshot sections.
///
/// Writers append to a `Vec<u8>` section buffer; the [`bin::Cursor`]
/// reader yields `std::io::Error` of kind `UnexpectedEof` on truncated
/// input so callers can surface truncation without panicking.
pub mod bin {
    /// Append a `u8`.
    pub fn put_u8(out: &mut Vec<u8>, v: u8) {
        out.push(v);
    }

    /// Append a `u32`, little-endian.
    pub fn put_u32(out: &mut Vec<u8>, v: u32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`, little-endian.
    pub fn put_u64(out: &mut Vec<u8>, v: u64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32`, little-endian bit pattern.
    pub fn put_f32(out: &mut Vec<u8>, v: f32) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f64`, little-endian bit pattern.
    pub fn put_f64(out: &mut Vec<u8>, v: f64) {
        out.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed (`u64`) byte slice.
    pub fn put_bytes(out: &mut Vec<u8>, v: &[u8]) {
        put_u64(out, v.len() as u64);
        out.extend_from_slice(v);
    }

    /// Append a length-prefixed (`u64`) `u16` slice, little-endian.
    pub fn put_u16s(out: &mut Vec<u8>, v: &[u16]) {
        put_u64(out, v.len() as u64);
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Append a length-prefixed (`u64`) `u32` slice, little-endian.
    pub fn put_u32s(out: &mut Vec<u8>, v: &[u32]) {
        put_u64(out, v.len() as u64);
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Append a length-prefixed (`u64`) `f32` slice, little-endian.
    pub fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
        put_u64(out, v.len() as u64);
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn eof(what: &str) -> std::io::Error {
        std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            format!("snapshot section truncated reading {what}"),
        )
    }

    /// Bounds-checked reader over a section payload.
    pub struct Cursor<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Cursor<'a> {
        pub fn new(buf: &'a [u8]) -> Cursor<'a> {
            Cursor { buf, pos: 0 }
        }

        /// Bytes not yet consumed.
        pub fn remaining(&self) -> usize {
            self.buf.len() - self.pos
        }

        /// Absolute position within the underlying buffer. Zero-copy
        /// section views use this to translate cursor-relative reads
        /// into offsets inside a memory-mapped snapshot.
        pub fn pos(&self) -> usize {
            self.pos
        }

        /// Take `n` raw bytes.
        pub fn take(&mut self, n: usize) -> std::io::Result<&'a [u8]> {
            if self.remaining() < n {
                return Err(eof("bytes"));
            }
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        pub fn get_u8(&mut self) -> std::io::Result<u8> {
            Ok(self.take(1)?[0])
        }

        pub fn get_u32(&mut self) -> std::io::Result<u32> {
            let b = self.take(4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }

        pub fn get_u64(&mut self) -> std::io::Result<u64> {
            let b = self.take(8)?;
            Ok(u64::from_le_bytes([
                b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
            ]))
        }

        pub fn get_f32(&mut self) -> std::io::Result<f32> {
            Ok(f32::from_le_bytes(self.get_u32()?.to_le_bytes()))
        }

        pub fn get_f64(&mut self) -> std::io::Result<f64> {
            Ok(f64::from_le_bytes(self.get_u64()?.to_le_bytes()))
        }

        /// Sanity-checked length prefix: must fit in the bytes left.
        pub(crate) fn get_len(&mut self, elem_bytes: usize) -> std::io::Result<usize> {
            let n = self.get_u64()? as usize;
            match n.checked_mul(elem_bytes) {
                Some(b) if b <= self.remaining() => Ok(n),
                _ => Err(eof("length-prefixed slice")),
            }
        }

        /// Read a length-prefixed byte slice.
        pub fn get_bytes(&mut self) -> std::io::Result<Vec<u8>> {
            let n = self.get_len(1)?;
            Ok(self.take(n)?.to_vec())
        }

        /// Read a length-prefixed `u16` slice.
        pub fn get_u16s(&mut self) -> std::io::Result<Vec<u16>> {
            let n = self.get_len(2)?;
            let b = self.take(n * 2)?;
            Ok(b.chunks_exact(2)
                .map(|c| u16::from_le_bytes([c[0], c[1]]))
                .collect())
        }

        /// Read a length-prefixed `u32` slice.
        pub fn get_u32s(&mut self) -> std::io::Result<Vec<u32>> {
            let n = self.get_len(4)?;
            let b = self.take(n * 4)?;
            Ok(b.chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }

        /// Read a length-prefixed `f32` slice.
        pub fn get_f32s(&mut self) -> std::io::Result<Vec<f32>> {
            let n = self.get_len(4)?;
            let b = self.take(n * 4)?;
            Ok(b.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        }
    }
}

/// Write fvecs: per vector, a little-endian u32 dim then dim f32s.
pub fn write_fvecs(path: &Path, rows: &[Vec<f32>]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for r in rows {
        w.write_all(&(r.len() as u32).to_le_bytes())?;
        for &v in r {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read fvecs.
pub fn read_fvecs(path: &Path) -> std::io::Result<Vec<Vec<f32>>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut rows = Vec::new();
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = u32::from_le_bytes(dim_buf) as usize;
        let mut buf = vec![0u8; dim * 4];
        r.read_exact(&mut buf)?;
        rows.push(
            buf.chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok(rows)
}

/// Write ivecs (u32 payloads, same framing as fvecs).
pub fn write_ivecs(path: &Path, rows: &[Vec<u32>]) -> std::io::Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    for r in rows {
        w.write_all(&(r.len() as u32).to_le_bytes())?;
        for &v in r {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read ivecs.
pub fn read_ivecs(path: &Path) -> std::io::Result<Vec<Vec<u32>>> {
    let mut r = BufReader::new(File::open(path)?);
    let mut rows = Vec::new();
    let mut dim_buf = [0u8; 4];
    loop {
        match r.read_exact(&mut dim_buf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e),
        }
        let dim = u32::from_le_bytes(dim_buf) as usize;
        let mut buf = vec![0u8; dim * 4];
        r.read_exact(&mut buf)?;
        rows.push(
            buf.chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect(),
        );
    }
    Ok(rows)
}

/// Write a 2-D f32 array as npy v1.0 (little-endian, C order).
pub fn write_npy_f32(path: &Path, rows: usize, cols: usize, data: &[f32]) -> std::io::Result<()> {
    assert_eq!(data.len(), rows * cols);
    let mut w = BufWriter::new(File::create(path)?);
    let header_body = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': ({rows}, {cols}), }}"
    );
    // pad so that 10 + len(header) is a multiple of 64, newline-terminated
    let unpadded = 10 + header_body.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    let header = format!("{header_body}{}\n", " ".repeat(pad));
    w.write_all(b"\x93NUMPY\x01\x00")?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    for &v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read a 2-D f32 npy (v1.x, little-endian, C order only).
pub fn read_npy_f32(path: &Path) -> std::io::Result<(usize, usize, Vec<f32>)> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "not an npy file",
        ));
    }
    let mut len_buf = [0u8; 2];
    r.read_exact(&mut len_buf)?;
    let hlen = u16::from_le_bytes(len_buf) as usize;
    let mut header = vec![0u8; hlen];
    r.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);
    if !header.contains("'<f4'") || header.contains("'fortran_order': True") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "only little-endian C-order f32 npy supported",
        ));
    }
    // parse "(rows, cols)"
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .ok_or_else(|| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, "bad npy shape")
        })?;
    let dims: Vec<usize> = shape_part
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    let (rows, cols) = match dims.len() {
        1 => (dims[0], 1),
        2 => (dims[0], dims[1]),
        _ => {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "only 1-D/2-D npy supported",
            ))
        }
    };
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    if buf.len() < rows * cols * 4 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "npy payload truncated",
        ));
    }
    let data = buf[..rows * cols * 4]
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok((rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("leanvec-io-test-{}-{name}", std::process::id()));
        p
    }

    #[test]
    fn fvecs_roundtrip() {
        let rows = vec![vec![1.0f32, 2.0, 3.0], vec![-4.5, 0.0, 9.25]];
        let p = tmp("a.fvecs");
        write_fvecs(&p, &rows).unwrap();
        assert_eq!(read_fvecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ivecs_roundtrip() {
        let rows = vec![vec![1u32, 2, 3], vec![7, 8]];
        let p = tmp("b.ivecs");
        write_ivecs(&p, &rows).unwrap();
        assert_eq!(read_ivecs(&p).unwrap(), rows);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_roundtrip() {
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        let p = tmp("c.npy");
        write_npy_f32(&p, 3, 4, &data).unwrap();
        let (r, c, d) = read_npy_f32(&p).unwrap();
        assert_eq!((r, c), (3, 4));
        assert_eq!(d, data);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn npy_rejects_garbage() {
        let p = tmp("d.npy");
        std::fs::write(&p, b"not-an-npy").unwrap();
        assert!(read_npy_f32(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_fvecs_reads_empty() {
        let p = tmp("e.fvecs");
        std::fs::write(&p, b"").unwrap();
        assert!(read_fvecs(&p).unwrap().is_empty());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn bin_roundtrip_all_types() {
        let mut buf = Vec::new();
        bin::put_u8(&mut buf, 7);
        bin::put_u32(&mut buf, 0xDEAD_BEEF);
        bin::put_u64(&mut buf, 1 << 40);
        bin::put_f32(&mut buf, -1.5);
        bin::put_f64(&mut buf, 2.25);
        bin::put_bytes(&mut buf, &[1, 2, 3]);
        bin::put_u16s(&mut buf, &[10, 20]);
        bin::put_u32s(&mut buf, &[30, 40, 50]);
        bin::put_f32s(&mut buf, &[0.5, -0.5]);
        let mut c = bin::Cursor::new(&buf);
        assert_eq!(c.get_u8().unwrap(), 7);
        assert_eq!(c.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(c.get_u64().unwrap(), 1 << 40);
        assert_eq!(c.get_f32().unwrap(), -1.5);
        assert_eq!(c.get_f64().unwrap(), 2.25);
        assert_eq!(c.get_bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(c.get_u16s().unwrap(), vec![10, 20]);
        assert_eq!(c.get_u32s().unwrap(), vec![30, 40, 50]);
        assert_eq!(c.get_f32s().unwrap(), vec![0.5, -0.5]);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn bin_cursor_rejects_truncation() {
        let mut buf = Vec::new();
        bin::put_f32s(&mut buf, &[1.0, 2.0, 3.0]);
        // cut mid-payload: the length prefix now exceeds the bytes left
        let cut = &buf[..buf.len() - 5];
        let mut c = bin::Cursor::new(cut);
        let err = c.get_f32s().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        // absurd length prefix must not allocate/panic
        let mut huge = Vec::new();
        bin::put_u64(&mut huge, u64::MAX);
        let mut c = bin::Cursor::new(&huge);
        assert!(c.get_u32s().is_err());
    }
}
