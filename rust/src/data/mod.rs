//! Datasets: synthetic embedding generators (stand-ins for the paper's
//! Table-1 roster — see DESIGN.md §Substitutions), exact ground truth,
//! recall metrics, and fvecs/ivecs/npy-lite I/O.

pub mod gt;
pub mod io;
pub mod synth;

pub use gt::{ground_truth, recall_at_k};
pub use synth::{generate, paper_datasets, Dataset, SynthSpec};
