//! Typed consistency violations for the deep fsck layer.
//!
//! Every structural checker in the crate — the Vamana/CSR graph, the
//! five [`crate::quant::ScoreStore`] kinds, [`crate::index::LeanVecIndex`],
//! [`crate::mutate::LiveIndex`], and [`crate::shard::ShardedIndex`] —
//! reports breakage by pushing [`Violation`]s into a shared vector
//! instead of panicking or printing. One checker, three consumers: the
//! `repro fsck` CLI, the `rust/tests/fsck.rs` corruption battery, and
//! the snapshot-corruption tests all call the same `check_invariants`
//! entry points, so what the CLI can detect is exactly what the tests
//! prove is detectable.
//!
//! Checkers must never panic on corrupt input: a checker that indexes
//! past a bound it was about to report would turn diagnosis into a
//! crash. They therefore re-derive every offset from first principles
//! (lengths, strides) before dereferencing anything.

use std::fmt;

/// One detected breakage: which layer found it, a stable machine-
/// checkable code, and a human-readable locator.
///
/// Codes are part of the tool's contract (tests assert on them):
/// `neighbor-out-of-range`, `self-loop`, `degree-overflow`,
/// `medoid-out-of-range`, `csr-offsets`, `payload-size-mismatch`,
/// `scale-not-positive`, `constant-not-finite`, `store-len-mismatch`,
/// `dim-mismatch`, `idmap-not-bijective`, `tombstone-bitmap`,
/// `insert-log-bounds`, `routing-seed`, `ext-id-overlap`,
/// `shard-count`.
#[derive(Debug, Clone)]
pub struct Violation {
    /// which structure was being checked ("graph", "primary-store", ...)
    pub layer: &'static str,
    /// stable kebab-case code naming the broken invariant
    pub code: &'static str,
    /// where / how it is broken, with the offending values
    pub detail: String,
}

impl Violation {
    pub fn new(layer: &'static str, code: &'static str, detail: impl Into<String>) -> Violation {
        Violation {
            layer,
            code,
            detail: detail.into(),
        }
    }
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}: {}", self.layer, self.code, self.detail)
    }
}

/// The result of one deep check: every violation found plus a short
/// summary of what was covered (so a clean report still shows the
/// check did real work).
#[derive(Debug, Default)]
pub struct FsckReport {
    pub violations: Vec<Violation>,
    /// one line per structure covered, e.g. "graph: 1000 nodes"
    pub checked: Vec<String>,
}

impl FsckReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Does the report contain a violation with this code (any layer)?
    pub fn has_code(&self, code: &str) -> bool {
        self.violations.iter().any(|v| v.code == code)
    }

    /// Merge `other` into `self`, re-tagging each of its violations
    /// and coverage lines with a sub-structure prefix (e.g. the shard
    /// ordinal) so multi-part reports stay attributable.
    pub fn absorb(&mut self, prefix: &str, other: FsckReport) {
        for mut v in other.violations {
            v.detail = format!("{prefix}: {}", v.detail);
            self.violations.push(v);
        }
        for line in other.checked {
            self.checked.push(format!("{prefix}: {line}"));
        }
    }
}

impl fmt::Display for FsckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for line in &self.checked {
            writeln!(f, "checked {line}")?;
        }
        if self.violations.is_empty() {
            write!(f, "fsck: clean")
        } else {
            for v in &self.violations {
                writeln!(f, "{v}")?;
            }
            write!(f, "fsck: {} violation(s)", self.violations.len())
        }
    }
}

/// Shared guard for the per-vector f32 constant arrays (norms, offsets):
/// pushes at most one `constant-not-finite` for the whole array, naming
/// the first offending row — corrupt stores can have millions.
pub fn check_finite(
    out: &mut Vec<Violation>,
    layer: &'static str,
    what: &str,
    values: &[f32],
) {
    if let Some((i, v)) = values
        .iter()
        .enumerate()
        .find(|(_, v)| !v.is_finite())
    {
        out.push(Violation::new(
            layer,
            "constant-not-finite",
            format!("{what}[{i}] = {v}"),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_display_and_codes() {
        let mut r = FsckReport::default();
        r.checked.push("graph: 10 nodes".into());
        assert!(r.is_clean());
        assert!(format!("{r}").contains("clean"));
        r.violations
            .push(Violation::new("graph", "self-loop", "node 3"));
        assert!(!r.is_clean());
        assert!(r.has_code("self-loop"));
        assert!(!r.has_code("degree-overflow"));
        let shown = format!("{r}");
        assert!(shown.contains("[graph] self-loop: node 3"));
        assert!(shown.contains("1 violation"));
    }

    #[test]
    fn absorb_prefixes_details() {
        let mut outer = FsckReport::default();
        let mut inner = FsckReport::default();
        inner
            .violations
            .push(Violation::new("store", "scale-not-positive", "delta[0]"));
        inner.checked.push("store: 5 rows".into());
        outer.absorb("shard 2", inner);
        assert_eq!(outer.violations.len(), 1);
        assert!(outer.violations[0].detail.starts_with("shard 2: "));
        assert!(outer.checked[0].starts_with("shard 2: "));
    }

    #[test]
    fn check_finite_reports_first_bad_row_only() {
        let mut out = Vec::new();
        check_finite(&mut out, "store", "norms", &[1.0, f32::NAN, f32::INFINITY]);
        assert_eq!(out.len(), 1);
        assert!(out[0].detail.contains("norms[1]"));
        out.clear();
        check_finite(&mut out, "store", "norms", &[0.0, -3.5]);
        assert!(out.is_empty());
    }
}
