//! Timing + summary statistics for the bench harness and the serving
//! engine's latency metrics (criterion is unavailable offline).

use std::time::{Duration, Instant};

/// Online summary of a sample of f64 observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Benchmark result for one named case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns * 1e-9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<48} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Measure `f` with warmup, targeting ~`target` of total measurement
/// time, batching iterations so per-call overhead stays negligible.
pub fn bench<F: FnMut()>(name: &str, target: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: how long does one call take?
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(20));
    let per_sample = (once.as_nanos() as f64).max(1.0);
    // Aim for ~60 samples of >=1 call each.
    let samples = 60usize;
    let budget = target.as_nanos() as f64 / samples as f64;
    let batch = (budget / per_sample).max(1.0).min(1e7) as usize;

    let mut summary = Summary::new();
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        summary.push(t.elapsed().as_nanos() as f64 / batch as f64);
        if summary.len() >= 8 && Instant::now().duration_since(t0) > target * 3 {
            break; // never run away on slow cases
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: summary.len() * batch,
        mean_ns: summary.mean(),
        p50_ns: summary.p50(),
        p99_ns: summary.p99(),
        stddev_ns: summary.stddev(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic_stats() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.push(0.0);
        s.push(10.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("µs"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with("s"));
    }
}
