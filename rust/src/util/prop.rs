//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Drives a property with seeded random cases; on failure it retries the
//! failing case with geometrically shrunk size hints and reports the
//! smallest reproduction seed. Used by rust/tests/prop_invariants.rs for
//! coordinator/graph/quantization invariants.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint handed to generators
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            seed: 0xC0FFEE,
            max_size: 128,
        }
    }
}

/// Context handed to each property case: a seeded RNG plus a size hint
/// that ramps up over the run (small cases first, like proptest).
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_gaussian(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.gaussian_f32()).collect()
    }
}

/// Run `property` over `config.cases` generated cases; panic with the
/// seed + case number on the first failure (after shrinking the size).
pub fn check<F>(name: &str, config: Config, property: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    for case in 0..config.cases {
        // Size ramps from tiny to max over the run.
        let size = 1 + (config.max_size - 1) * case / config.cases.max(1);
        let case_seed = config.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let mut g = Gen {
            rng: &mut rng,
            size,
        };
        if let Err(msg) = property(&mut g) {
            // Shrink: try smaller sizes with the same seed to find the
            // smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                let mut g = Gen {
                    rng: &mut rng,
                    size: s,
                };
                if let Err(m) = property(&mut g) {
                    smallest = (s, m);
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 shrunk size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn passing_property_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = AtomicUsize::new(0);
        check("always-true", Config::default(), |g| {
            let _ = g.usize_in(0, 10);
            count.fetch_add(1, Ordering::Relaxed);
            Ok(())
        });
        assert_eq!(count.load(Ordering::Relaxed), Config::default().cases);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", Config::default(), |_| {
            Err("nope".to_string())
        });
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn generators_respect_bounds() {
        check("bounds", Config::default(), |g| {
            let n = g.usize_in(3, 9);
            if !(3..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let x = g.f32_in(-2.0, 2.0);
            if !(-2.0..=2.0).contains(&x) {
                return Err(format!("f32_in out of range: {x}"));
            }
            let v = g.vec_f32(g.size, 0.0, 1.0);
            if v.len() != g.size {
                return Err("wrong length".into());
            }
            Ok(())
        });
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn size_ramps_up() {
        let seen = std::sync::Mutex::new(Vec::new());
        check("sizes", Config { cases: 16, ..Config::default() }, |g| {
            seen.lock().unwrap().push(g.size);
            Ok(())
        });
        let sizes = seen.into_inner().unwrap();
        assert!(sizes[0] < *sizes.last().unwrap());
    }
}
