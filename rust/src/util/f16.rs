//! IEEE 754 binary16 (half precision) codec.
//!
//! The paper stores secondary vectors as FP16; the `half` crate is not
//! available offline, so the conversion is implemented here. Round-trip
//! uses round-to-nearest-even, handles subnormals, infinities and NaN.

/// Encode an `f32` to its nearest IEEE binary16 bit pattern.
pub fn f32_to_f16(value: f32) -> u16 {
    let bits = value.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN: keep a non-zero mantissa bit for NaN.
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // normal half
        let mut m = mant >> 13; // keep 10 bits
        let rest = mant & 0x1FFF;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // mantissa rounded over; bump exponent
            m = 0;
            he += 1;
            if he >= 0x1F {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e < -25 {
        return sign; // underflow to signed zero
    }
    // subnormal half: implicit leading 1 becomes explicit.
    // m16 = round(full * 2^(e+1)) since value = full * 2^(e-23) and one
    // subnormal-half ulp is 2^-24.
    let full = mant | 0x0080_0000;
    let shift = (-e - 1) as u32; // 14..=24
    let m = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half_ulp = 1u32 << (shift - 1);
    let mut m16 = m as u16;
    if rem > half_ulp || (rem == half_ulp && (m16 & 1) == 1) {
        m16 += 1;
    }
    sign | m16
}

/// Decode an IEEE binary16 bit pattern to `f32`.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize. value = m * 2^-24; after shifting m
            // up to its leading bit at position 10, the f32 exponent is
            // 127 - 24 + (10 - shifts) = 113 + (position adjustments),
            // tracked incrementally below.
            let mut e: i32 = 113; // exponent if m already has bit 10 set
            let mut m = m;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | ((e as u32) << 23) | (m << 13)
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Encode a slice.
pub fn encode_slice(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16(x)).collect()
}

/// Full f16 -> f32 decode table (64K entries, 256 KiB). The scoring hot
/// loop is memory-bound on the codes; a table lookup beats the bit
/// manipulation by ~2x on this testbed (EXPERIMENTS.md §Perf).
pub fn decode_table() -> &'static [f32; 65536] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Box<[f32; 65536]>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = vec![0.0f32; 65536].into_boxed_slice();
        for (h, v) in t.iter_mut().enumerate() {
            *v = f16_to_f32(h as u16);
        }
        t.try_into().unwrap()
    })
}

/// Decode a slice.
pub fn decode_slice(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| f16_to_f32(h)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(f16_to_f32(f32_to_f16(x)), x, "{x}");
        }
    }

    #[test]
    fn special_values() {
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xC000), -2.0);
    }

    #[test]
    fn overflow_saturates_to_inf() {
        assert_eq!(f32_to_f16(1.0e6), 0x7C00);
        assert_eq!(f32_to_f16(-1.0e6), 0xFC00);
        assert_eq!(f32_to_f16(65504.0), 0x7BFF); // max finite half
    }

    #[test]
    fn subnormals_roundtrip() {
        // smallest positive subnormal half = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f32_to_f16(tiny), 0x0001);
        assert_eq!(f16_to_f32(0x0001), tiny);
        // underflow below half of the smallest subnormal -> zero
        assert_eq!(f32_to_f16(2.0f32.powi(-26)), 0x0000);
    }

    #[test]
    fn relative_error_within_half_ulp() {
        // unit-range values: |x - roundtrip(x)| <= 2^-11 * |x|
        let mut worst = 0.0f32;
        for i in 1..10_000 {
            let x = i as f32 / 10_000.0;
            let r = f16_to_f32(f32_to_f16(x));
            worst = worst.max((x - r).abs() / x);
        }
        assert!(worst <= 1.0 / 2048.0, "{worst}");
    }

    #[test]
    fn exhaustive_decode_encode_identity() {
        // every finite half value must encode back to itself
        for h in 0u16..=0xFFFF {
            let exp = (h >> 10) & 0x1F;
            if exp == 0x1F {
                continue; // inf/NaN handled elsewhere
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "h={h:#06x}");
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs = vec![0.5, -1.25, 3.75, 100.0];
        assert_eq!(decode_slice(&encode_slice(&xs)), xs);
    }
}
