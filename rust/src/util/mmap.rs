//! Read-only memory mapping and Cow-style array backing for
//! mmap-served snapshots.
//!
//! The offline build vendors no `libc`, so [`Mmap`] binds the three
//! syscalls it needs (`mmap`/`munmap`/`madvise`) directly via
//! `extern "C"` on unix; every other platform falls back to reading
//! the file into an owned buffer, which keeps the API total.
//!
//! [`Arr`] is the backing abstraction threaded through the score
//! stores and the graph CSR: either an owned `Vec<T>` (the historical
//! heap path) or a typed window borrowed straight out of an
//! `Arc<Mmap>`. Borrowing only happens when the bytes in the file are
//! correctly aligned for `T` *and* the host is little-endian (the
//! snapshot wire format is LE); otherwise readers decode into owned
//! memory exactly as before and bump a fallback counter so
//! `load_mmap` can warn. `Deref<Target = [T]>` means all existing
//! slice-consuming code (scoring kernels, section writers) compiles
//! unchanged against either backing.

use std::ops::Deref;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::data::io::bin;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn madvise(addr: *mut c_void, len: usize, advice: c_int) -> c_int;
    }
}

/// Access-pattern hints forwarded to `madvise`. Best-effort: a kernel
/// that ignores them only loses the prefetch/eviction optimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// Default kernel readahead.
    Normal,
    /// Random access: disable readahead (graph traversal).
    Random,
    /// Sequential scan: aggressive readahead (CRC verification pass).
    Sequential,
    /// Expect access soon: start faulting pages in.
    WillNeed,
    /// Drop the resident pages; they reload from disk on next touch.
    /// This is how the bigger-than-RAM bench arm caps its resident set.
    DontNeed,
}

impl Advice {
    #[cfg(unix)]
    fn code(self) -> i32 {
        match self {
            Advice::Normal => 0,
            Advice::Random => 1,
            Advice::Sequential => 2,
            Advice::WillNeed => 3,
            Advice::DontNeed => 4,
        }
    }
}

/// A read-only, private, whole-file memory mapping.
///
/// On unix the pages are faulted in lazily by the OS and never copied
/// into the heap; elsewhere the constructor silently degrades to an
/// owned read of the file so callers need no platform branches.
pub struct Mmap {
    #[cfg(unix)]
    ptr: *mut u8,
    #[cfg(not(unix))]
    buf: Vec<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE and never mutated or
// remapped after construction, so concurrent shared reads from any
// thread are fine; the raw pointer is only freed in Drop, which takes
// `&mut self` and therefore exclusive access.
unsafe impl Send for Mmap {}
// SAFETY: see the Send argument above — read-only shared state.
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only. Empty files map to an empty slice without
    /// touching `mmap` (a zero-length mapping is EINVAL on Linux).
    pub fn open(path: &Path) -> std::io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Ok(Mmap {
                    ptr: std::ptr::null_mut(),
                    len: 0,
                });
            }
            // SAFETY: plain FFI syscall with no pointer preconditions:
            // addr is null (kernel chooses placement), `len > 0` was
            // just checked (zero-length mappings are EINVAL), and `fd`
            // is a live descriptor borrowed from `file`, which outlives
            // the call. The result is validated against MAP_FAILED
            // before use.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED {
                return Err(std::io::Error::last_os_error());
            }
            // `file` closes here; the mapping keeps the pages alive.
            Ok(Mmap {
                ptr: ptr as *mut u8,
                len,
            })
        }
        #[cfg(not(unix))]
        {
            let buf = std::fs::read(path)?;
            let len = buf.len();
            Ok(Mmap { buf, len })
        }
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return &[];
            }
            // SAFETY: `ptr` came from a successful PROT_READ mmap of
            // exactly `len` bytes, is non-null (len > 0 checked above),
            // stays valid until Drop unmaps it, and the pages are never
            // written — so a shared `&[u8]` view for `&self`'s lifetime
            // is sound.
            return unsafe { std::slice::from_raw_parts(self.ptr, self.len) };
        }
        #[cfg(not(unix))]
        {
            return &self.buf;
        }
    }

    /// Length of the mapping in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Hint the kernel about the upcoming access pattern over the
    /// whole mapping. Errors are ignored: advice is an optimization,
    /// never a correctness requirement.
    pub fn advise(&self, advice: Advice) {
        #[cfg(unix)]
        {
            if self.len > 0 {
                // SAFETY: `(ptr, len)` is exactly the live mapping
                // created in `open`; madvise only attaches a hint to
                // those pages and cannot invalidate the mapping. The
                // return value is deliberately ignored (advice is
                // best-effort).
                unsafe {
                    sys::madvise(self.ptr as *mut _, self.len, advice.code());
                }
            }
        }
        #[cfg(not(unix))]
        {
            let _ = advice;
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        {
            if self.len > 0 {
                // SAFETY: `(ptr, len)` is the exact region returned by
                // mmap in `open` and this Drop is the only unmap; no
                // `&[u8]` view can outlive it because every view
                // borrows `&self` (direct slices) or holds the owning
                // `Arc<Mmap>` (Arr::Mapped), keeping the value alive.
                unsafe {
                    sys::munmap(self.ptr as *mut _, self.len);
                }
            }
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len).finish()
    }
}

/// Cow-style backing for a typed array: an owned `Vec<T>` or a window
/// borrowed from a shared [`Mmap`]. Dereferences to `&[T]` either way.
pub enum Arr<T: Copy> {
    Owned(Vec<T>),
    Mapped {
        map: Arc<Mmap>,
        /// Byte offset of the first element within the mapping.
        off: usize,
        /// Element (not byte) count.
        len: usize,
    },
}

impl<T: Copy> Arr<T> {
    /// Borrow `len` elements of `T` starting `off` bytes into `map`.
    /// Returns `None` (caller decodes into owned memory instead) when
    /// the window is out of bounds, the bytes are misaligned for `T`,
    /// or the host is big-endian (the wire format is little-endian, so
    /// reinterpreting raw bytes would be wrong there).
    pub fn from_map(map: &Arc<Mmap>, off: usize, len: usize) -> Option<Arr<T>> {
        if !cfg!(target_endian = "little") {
            return None;
        }
        if len == 0 {
            return Some(Arr::Owned(Vec::new()));
        }
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        let addr = map.as_slice().as_ptr() as usize + off;
        if addr % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(Arr::Mapped {
            map: Arc::clone(map),
            off,
            len,
        })
    }

    /// True when the data lives in the page cache, not the heap.
    pub fn is_mapped(&self) -> bool {
        matches!(self, Arr::Mapped { .. })
    }

    /// Convert to the owned representation in place (copying the
    /// mapped bytes once) and return the vector for mutation. Mutable
    /// paths — live inserts, compaction — call this so a mapped index
    /// transparently upgrades to heap backing when it must change.
    pub fn make_owned(&mut self) -> &mut Vec<T> {
        if self.is_mapped() {
            *self = Arr::Owned(self.to_vec());
        }
        match self {
            Arr::Owned(v) => v,
            Arr::Mapped { .. } => unreachable!("just converted to owned"),
        }
    }
}

impl<T: Copy> Deref for Arr<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Arr::Owned(v) => v,
            // SAFETY: `from_map` is the only constructor of this
            // variant and validated at creation that `off + len *
            // size_of::<T>()` lies inside the mapping, that the base
            // address is aligned for `T`, and that the host is little-
            // endian (matching the wire format). The window stays valid
            // because this variant holds the `Arc<Mmap>` that owns the
            // pages, and the mapping is immutable for its whole life.
            Arr::Mapped { map, off, len } => unsafe {
                std::slice::from_raw_parts(
                    map.as_slice().as_ptr().add(*off) as *const T,
                    *len,
                )
            },
        }
    }
}

impl<T: Copy> From<Vec<T>> for Arr<T> {
    fn from(v: Vec<T>) -> Arr<T> {
        Arr::Owned(v)
    }
}

impl<T: Copy> Default for Arr<T> {
    fn default() -> Arr<T> {
        Arr::Owned(Vec::new())
    }
}

impl<T: Copy> Clone for Arr<T> {
    fn clone(&self) -> Arr<T> {
        match self {
            Arr::Owned(v) => Arr::Owned(v.clone()),
            Arr::Mapped { map, off, len } => Arr::Mapped {
                map: Arc::clone(map),
                off: *off,
                len: *len,
            },
        }
    }
}

impl<T: Copy + PartialEq> PartialEq for Arr<T> {
    fn eq(&self, other: &Arr<T>) -> bool {
        **self == **other
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for Arr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "owned" };
        write!(f, "Arr<{kind}>({:?})", &**self)
    }
}

/// Where a section payload lives inside a mapped snapshot, plus the
/// shared counter of arrays that had to fall back to owned decoding.
#[derive(Clone)]
pub struct SectionSrc {
    pub map: Arc<Mmap>,
    /// Absolute byte offset of the section payload within the map.
    pub base: usize,
    pub fallbacks: Arc<AtomicUsize>,
}

impl SectionSrc {
    pub fn note_fallback(&self) {
        // ORDERING: Relaxed — a monotonically increasing diagnostic
        // counter read once after loading finishes; it guards no data
        // and needs no happens-before edge.
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
        // mirrored process-wide for the metrics exposition
        crate::obs::handles().mmap_fallbacks.inc();
    }
}

macro_rules! get_arr {
    ($name:ident, $ty:ty, $elem:expr, $decode:expr) => {
        /// Read a length-prefixed array: borrowed from the map when a
        /// `SectionSrc` is given and the data is aligned, owned
        /// (decoded, exactly like the historical reader) otherwise.
        /// The cursor MUST be iterating the section payload slice of
        /// `src.map` itself, so `src.base + cur.pos()` addresses the
        /// raw element bytes inside the mapping.
        pub fn $name(
            cur: &mut bin::Cursor,
            src: Option<&SectionSrc>,
        ) -> std::io::Result<Arr<$ty>> {
            let n = cur.get_len($elem)?;
            let data_off = cur.pos();
            let bytes = cur.take(n * $elem)?;
            if let Some(s) = src {
                if let Some(arr) = Arr::<$ty>::from_map(&s.map, s.base + data_off, n) {
                    return Ok(arr);
                }
                s.note_fallback();
            }
            #[allow(clippy::redundant_closure_call)]
            Ok(Arr::Owned(($decode)(bytes)))
        }
    };
}

get_arr!(get_bytes_arr, u8, 1, |b: &[u8]| b.to_vec());
get_arr!(get_u16s_arr, u16, 2, |b: &[u8]| b
    .chunks_exact(2)
    .map(|c| u16::from_le_bytes([c[0], c[1]]))
    .collect::<Vec<u16>>());
get_arr!(get_u32s_arr, u32, 4, |b: &[u8]| b
    .chunks_exact(4)
    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    .collect::<Vec<u32>>());
get_arr!(get_f32s_arr, f32, 4, |b: &[u8]| b
    .chunks_exact(4)
    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    .collect::<Vec<f32>>());

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("leanvec-mmap-test-{}-{name}", std::process::id()));
        p
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn map_reads_file_bytes() {
        let p = tmp("a.bin");
        let data: Vec<u8> = (0..=255).collect();
        std::fs::write(&p, &data).unwrap();
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.len(), 256);
        assert_eq!(m.as_slice(), &data[..]);
        m.advise(Advice::Sequential);
        m.advise(Advice::Random);
        m.advise(Advice::DontNeed);
        assert_eq!(m.as_slice(), &data[..]);
        drop(m);
        std::fs::remove_file(&p).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn empty_file_maps_empty() {
        let p = tmp("b.bin");
        std::fs::write(&p, b"").unwrap();
        let m = Mmap::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.as_slice(), b"");
        m.advise(Advice::WillNeed);
        std::fs::remove_file(&p).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn missing_file_errors() {
        assert!(Mmap::open(&tmp("definitely-missing.bin")).is_err());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn arr_borrows_aligned_and_falls_back_misaligned() {
        let p = tmp("c.bin");
        let vals = [1.0f32, -2.5, 3.25, 0.0];
        let mut raw = Vec::new();
        for v in vals {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        // one leading pad byte => offset 1 is misaligned, offset 4 ok
        let mut file = vec![0u8; 4];
        file.extend_from_slice(&raw);
        std::fs::write(&p, &file).unwrap();
        let map = Arc::new(Mmap::open(&p).unwrap());

        let ok = Arr::<f32>::from_map(&map, 4, 4).expect("aligned window borrows");
        assert!(ok.is_mapped());
        assert_eq!(&*ok, &vals[..]);

        assert!(Arr::<f32>::from_map(&map, 1, 4).is_none(), "misaligned");
        assert!(Arr::<f32>::from_map(&map, 4, 5).is_none(), "out of bounds");

        // clone shares the map; make_owned copies out
        let mut c = ok.clone();
        assert!(c.is_mapped());
        c.make_owned().push(9.0);
        assert!(!c.is_mapped());
        assert_eq!(c.len(), 5);
        assert_eq!(&ok[..], &vals[..], "original untouched");

        drop((ok, c, map));
        std::fs::remove_file(&p).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn cursor_arr_helpers_borrow_or_decode() {
        let p = tmp("d.bin");
        // payload: 8 pad bytes, then a length-prefixed f32 slice whose
        // data lands at absolute offset 8 + 8 = 16 (aligned)
        let mut payload = vec![0u8; 8];
        bin::put_f32s(&mut payload, &[5.0, 6.0, 7.0]);
        bin::put_u32s(&mut payload, &[10, 20]);
        std::fs::write(&p, &payload).unwrap();
        let map = Arc::new(Mmap::open(&p).unwrap());
        let src = SectionSrc {
            map: Arc::clone(&map),
            base: 0,
            fallbacks: Arc::new(AtomicUsize::new(0)),
        };

        let mut cur = bin::Cursor::new(map.as_slice());
        cur.take(8).unwrap();
        let f = get_f32s_arr(&mut cur, Some(&src)).unwrap();
        assert!(f.is_mapped());
        assert_eq!(&*f, &[5.0, 6.0, 7.0]);
        // after 3 f32s the u32 data offset is 16+12+8 = 36: aligned too
        let u = get_u32s_arr(&mut cur, Some(&src)).unwrap();
        assert_eq!(&*u, &[10, 20]);
        assert_eq!(src.fallbacks.load(Ordering::Relaxed), 0);

        // without a src everything is owned
        let mut cur = bin::Cursor::new(map.as_slice());
        cur.take(8).unwrap();
        let f = get_f32s_arr(&mut cur, None).unwrap();
        assert!(!f.is_mapped());
        assert_eq!(&*f, &[5.0, 6.0, 7.0]);

        drop((f, u, src, map));
        std::fs::remove_file(&p).ok();
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn misaligned_cursor_read_counts_fallback() {
        let p = tmp("e.bin");
        // 1 pad byte: f32 data starts at 1 + 8 = 9, misaligned
        let mut payload = vec![0u8; 1];
        bin::put_f32s(&mut payload, &[1.0, 2.0]);
        std::fs::write(&p, &payload).unwrap();
        let map = Arc::new(Mmap::open(&p).unwrap());
        let src = SectionSrc {
            map: Arc::clone(&map),
            base: 0,
            fallbacks: Arc::new(AtomicUsize::new(0)),
        };
        let mut cur = bin::Cursor::new(map.as_slice());
        cur.take(1).unwrap();
        let f = get_f32s_arr(&mut cur, Some(&src)).unwrap();
        assert!(!f.is_mapped());
        assert_eq!(&*f, &[1.0, 2.0]);
        assert_eq!(src.fallbacks.load(Ordering::Relaxed), 1);
        drop((f, src, map));
        std::fs::remove_file(&p).ok();
    }
}
