//! Work-stealing-free, fixed-size thread pool + `parallel_for` helper.
//!
//! tokio/rayon are unavailable offline; graph construction and the
//! serving engine only need (a) fire-and-forget jobs and (b) a blocking
//! chunked parallel-for, both of which std::thread covers. On the 1-core
//! CI testbed the pool degrades gracefully to sequential execution.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are dispatched over a shared channel.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// `size == 0` selects `available_parallelism()`.
    pub fn new(size: usize) -> Self {
        let size = if size == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            size
        };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("leanvec-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            size,
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(job))
            .expect("worker alive");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for every `i in 0..n` across `threads` scoped workers.
///
/// Indices are handed out via an atomic cursor in `chunk`-sized spans, so
/// uneven work (e.g. graph-node insertion) balances automatically.
/// `f` must be `Sync`; use interior mutability / index-disjoint writes.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, chunk: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    let chunk = chunk.max(1);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                // ORDERING: Relaxed — the RMW's atomicity alone makes
                // chunk claims disjoint; workers share no other state
                // through the cursor, and scope join publishes results.
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + chunk).min(n) {
                    f(i);
                }
            });
        }
    });
}

/// Resolve a thread-count knob: `0` selects `available_parallelism()`.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Rows handled per task in [`parallel_chunked`]: fixed (not derived
/// from the thread count) so chunked results are identical for every
/// thread count.
pub const CHUNK_ROWS: usize = 256;

/// Fan `f(start, end)` over [`CHUNK_ROWS`]-sized index ranges across
/// `threads` workers, returning per-chunk outputs in chunk order
/// (callers concatenate them serially). The shared scaffolding for the
/// chunk-parallel store encoders and database projection: per-row work
/// is pure, so results are bit-identical to a serial loop.
pub fn parallel_chunked<T: Send, F: Fn(usize, usize) -> T + Sync>(
    n_rows: usize,
    threads: usize,
    f: F,
) -> Vec<T> {
    let n_chunks = n_rows.div_ceil(CHUNK_ROWS);
    parallel_map(n_chunks, threads, |ci| {
        let start = ci * CHUNK_ROWS;
        f(start, (start + CHUNK_ROWS).min(n_rows))
    })
}

/// Map `f` over 0..n in parallel, collecting results in index order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = std::sync::Mutex::new(&mut out);
        // Index-disjoint writes via raw pointer would be faster, but n is
        // small wherever this is used; a mutexed vector keeps it safe.
        parallel_for(n, threads, 1, |i| {
            let v = f(i);
            slots.lock().unwrap()[i] = Some(v);
        });
    }
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop joins all workers.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 4, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_for_zero_items_is_noop() {
        parallel_for(0, 4, 16, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_chunked_covers_ranges_in_order() {
        let parts = parallel_chunked(600, 4, |start, end| (start, end));
        assert_eq!(parts, vec![(0, 256), (256, 512), (512, 600)]);
        assert!(parallel_chunked(0, 4, |s, e| (s, e)).is_empty());
        // thread count never changes the output
        assert_eq!(parts, parallel_chunked(600, 1, |start, end| (start, end)));
    }

    #[test]
    fn resolve_threads_zero_is_parallelism() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn single_thread_fallback() {
        let mut seen = vec![false; 10];
        let cell = std::sync::Mutex::new(&mut seen);
        parallel_for(10, 1, 4, |i| {
            cell.lock().unwrap()[i] = true;
        });
        assert!(seen.iter().all(|&s| s));
    }
}
