//! From-scratch substrate utilities.
//!
//! The offline build environment only vendors the `xla` crate's
//! dependency tree, so everything this crate needs beyond that —
//! JSON, half-precision floats, RNG, a thread pool, CLI parsing, a
//! property-testing harness, and bench statistics — is implemented here.

pub mod cli;
pub mod f16;
pub mod invariants;
pub mod json;
pub mod mmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
