//! From-scratch substrate utilities.
//!
//! The offline build environment only vendors the `xla` crate's
//! dependency tree, so everything this crate needs beyond that —
//! JSON, half-precision floats, RNG, a thread pool, CLI parsing, a
//! property-testing harness, and bench statistics — is implemented here.

pub mod cancel;
pub mod cli;
pub mod f16;
// Deterministic fault injection for the chaos tests and CI soak. Only
// compiled into test builds (lib unit tests) or when the `failpoints`
// feature is on (integration chaos tests, release soak binaries) — the
// production serve path carries zero failpoint branches otherwise.
#[cfg(any(test, feature = "failpoints"))]
pub mod failpoints;
pub mod invariants;
pub mod json;
pub mod mmap;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
