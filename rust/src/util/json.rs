//! Minimal JSON parser + serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifest,
//! experiment configs and result dumps: objects, arrays, strings with
//! escapes, numbers, booleans, null. Numbers are stored as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // -- builders --------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn keyword(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err("invalid keyword"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Str("c".into())
        );
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"x": [1, 2.5, "s", false], "y": {"z": []}}"#;
        let j = Json::parse(src).unwrap();
        for text in [j.to_string(), j.to_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\" 1}", "tru", "1 2"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":[{"name":"fw_step_D768_d160",
            "file":"fw_step_D768_d160.hlo.txt","fn":"fw_step","D":768,"d":160,
            "inputs":[{"shape":[160,768],"dtype":"f32"}],
            "outputs":[{"shape":[160,768],"dtype":"f32"}]}]}"#;
        let j = Json::parse(src).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("D").unwrap().as_usize().unwrap(), 768);
        assert_eq!(
            arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
