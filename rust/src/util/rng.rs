//! Deterministic pseudo-random number generation (no external crates).
//!
//! `SplitMix64` seeds a `Xoshiro256**` generator; Gaussian samples come
//! from the Marsaglia polar method. Experiments are reproducible: every
//! generator is created from an explicit seed.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian sample from the polar method
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-thread generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via the Marsaglia polar method.
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * k);
                return u * k;
            }
        }
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// k distinct indices sampled uniformly from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // rejection sampling with a seen-set for sparse draws
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100, 5), (100, 50), (10, 10)] {
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), k);
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
