//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Model: `repro <subcommand> [positional...] [--flag value] [--switch]`.
//! Typed getters with defaults keep call sites terse.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (first token = subcommand).
    pub fn parse(tokens: &[String]) -> Args {
        let mut args = Args::default();
        let mut it = tokens.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // `--key=value`, `--key value`, or switch
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        args.switches.push(name.to_string());
                    } else {
                        args.flags
                            .insert(name.to_string(), it.next().unwrap().clone());
                    }
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        args
    }

    pub fn from_env() -> Args {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&tokens)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Like [`Args::usize`], but a present-yet-unparsable value is an
    /// error instead of silently falling back to the default (the
    /// up-front CLI validation path).
    pub fn checked_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an unsigned integer, got '{v}'")),
        }
    }

    /// [`Args::checked_usize`] for `f64` flags.
    pub fn checked_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// Comma-separated list of usize, e.g. `--dims 96,128,160`.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.flags.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .filter_map(|s| s.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(&toks("build --dataset rqa-768 --dim 160 --verbose"));
        assert_eq!(a.subcommand.as_deref(), Some("build"));
        assert_eq!(a.str("dataset", ""), "rqa-768");
        assert_eq!(a.usize("dim", 0), 160);
        assert!(a.switch("verbose"));
    }

    #[test]
    fn equals_form_and_positional() {
        let a = Args::parse(&toks("experiment fig4 --out=results --k=10"));
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.str("out", ""), "results");
        assert_eq!(a.usize("k", 0), 10);
    }

    #[test]
    fn trailing_switch() {
        let a = Args::parse(&toks("run --fast"));
        assert!(a.switch("fast"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&toks("run"));
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.f64("missing", 0.5), 0.5);
        assert_eq!(a.str("missing", "x"), "x");
        assert!(!a.switch("missing"));
    }

    #[test]
    fn checked_getters_reject_garbage_but_accept_absent() {
        let a = Args::parse(&toks("search --k banana --nprobe 8"));
        assert!(a.checked_usize("k", 10).is_err());
        assert_eq!(a.checked_usize("nprobe", 1), Ok(8));
        assert_eq!(a.checked_usize("window", 50), Ok(50), "absent -> default");
        let b = Args::parse(&toks("mutate --insert-rate 0.2x"));
        assert!(b.checked_f64("insert-rate", 0.0).is_err());
        assert_eq!(b.checked_f64("delete-rate", 0.1), Ok(0.1));
    }

    #[test]
    fn usize_list_parses() {
        let a = Args::parse(&toks("x --dims 96,128,160"));
        assert_eq!(a.usize_list("dims", &[1]), vec![96, 128, 160]);
        assert_eq!(a.usize_list("other", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn no_subcommand_when_first_is_flag() {
        let a = Args::parse(&toks("--help"));
        assert_eq!(a.subcommand, None);
        assert!(a.switch("help"));
    }
}
