//! Cooperative cancellation for in-flight searches.
//!
//! A [`CancelToken`] is a poll-only flag shared between the engine
//! worker that owns a request and the per-shard traversals answering
//! it: the coordinator arms it with the request's deadline (or trips
//! it explicitly), and the beam search polls it every few dozen
//! expansions. There is no wakeup machinery — traversal loops are
//! short and hot, so polling an atomic (plus an occasional clock read)
//! is both cheap and sufficient to bound a request's latency.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Shared cancellation flag with an optional absolute deadline.
///
/// `is_cancelled` latches: once the flag is observed set (explicitly or
/// because the deadline passed), every later poll — on any thread —
/// reports cancelled without reading the clock again.
#[derive(Debug, Default)]
pub struct CancelToken {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token that only trips when [`cancel`](Self::cancel) is called.
    pub fn new() -> Self {
        CancelToken {
            cancelled: AtomicBool::new(false),
            deadline: None,
        }
    }

    /// A token that trips itself once `deadline` passes.
    pub fn with_deadline(deadline: Instant) -> Self {
        CancelToken {
            cancelled: AtomicBool::new(false),
            deadline: Some(deadline),
        }
    }

    /// A token whose deadline is `timeout` from now.
    pub fn after(timeout: Duration) -> Self {
        Self::with_deadline(Instant::now() + timeout)
    }

    /// Trip the token explicitly (idempotent).
    pub fn cancel(&self) {
        // ORDERING: Relaxed — the flag is advisory; pollers only use it
        // to stop early, never to synchronize reads of other data.
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// The absolute deadline, if one was armed.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// Poll: true once the token is tripped or its deadline has passed.
    ///
    /// The fast path is a single relaxed load; the clock is only read
    /// while the flag is still clear *and* a deadline is armed. Callers
    /// on hot loops should further fold this under an every-N-iterations
    /// check so the clock read amortizes.
    pub fn is_cancelled(&self) -> bool {
        // ORDERING: Relaxed — see `cancel`; a slightly stale read only
        // delays the stop by one poll interval.
        if self.cancelled.load(Ordering::Relaxed) {
            return true;
        }
        match self.deadline {
            Some(d) if Instant::now() >= d => {
                self.cancel(); // latch so later polls skip the clock
                true
            }
            _ => false,
        }
    }

    /// Time left until the deadline (None when no deadline is armed;
    /// zero once it has passed).
    pub fn remaining(&self) -> Option<Duration> {
        self.deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_cancel_latches() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled());
        assert!(t.is_cancelled(), "stays tripped");
    }

    #[test]
    fn deadline_in_the_past_trips_immediately() {
        let t = CancelToken::after(Duration::ZERO);
        assert!(t.is_cancelled());
    }

    #[test]
    fn far_deadline_does_not_trip() {
        let t = CancelToken::after(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        assert!(t.remaining().unwrap() > Duration::from_secs(3000));
    }

    #[test]
    fn no_deadline_means_no_remaining() {
        assert_eq!(CancelToken::new().remaining(), None);
        assert_eq!(CancelToken::new().deadline(), None);
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let t = Arc::new(CancelToken::new());
        let t2 = Arc::clone(&t);
        let h = std::thread::spawn(move || t2.cancel());
        h.join().unwrap();
        assert!(t.is_cancelled());
    }
}
