//! Deterministic fault injection for chaos tests and the CI soak.
//!
//! A failpoint is a named site in the serve path (`slow_shard`,
//! `panic_shard`, `io_error_on_load`) that tests arm with an [`Action`]
//! — sleep, panic, or injected error — optionally scoped to one shard
//! index and/or a bounded number of firings. Production builds compile
//! none of this: the module and every call site are gated behind
//! `cfg(any(test, feature = "failpoints"))`.
//!
//! Arming is programmatic ([`set`]/[`clear`]/[`clear_all`]) or via the
//! `LEANVEC_FAILPOINTS` environment variable, parsed once on first use:
//!
//! ```text
//! LEANVEC_FAILPOINTS=slow_shard=sleep:50@1,panic_shard=panic@2#3
//! ```
//!
//! grammar per entry: `name=action[:arg][@shard][#hits]` where action is
//! `sleep:<ms>`, `panic`, or `error`; `@shard` restricts to one shard
//! index; `#hits` fires at most that many times.
//!
//! The catalog of sites the serve path consults lives in
//! docs/ROBUSTNESS.md. Because the registry is process-global, tests
//! that arm failpoints must serialize on a shared lock and `clear_all`
//! when done.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock, PoisonError};

/// What an armed failpoint does when its site is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Sleep this many milliseconds, then continue normally.
    Sleep(u64),
    /// Panic with a recognizable `failpoint <name> fired` message.
    Panic,
    /// Report an injected error to the call site (only sites that can
    /// fail check for this; others ignore it).
    Error,
}

/// An armed failpoint: the action plus its scope.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Failpoint {
    pub action: Action,
    /// Fire only when the site reports this shard index (None = all).
    pub shard: Option<usize>,
    /// Remaining firings before the point disarms (None = unlimited).
    pub hits: Option<u64>,
}

impl Failpoint {
    pub fn new(action: Action) -> Failpoint {
        Failpoint {
            action,
            shard: None,
            hits: None,
        }
    }

    pub fn on_shard(mut self, shard: usize) -> Failpoint {
        self.shard = Some(shard);
        self
    }

    pub fn times(mut self, hits: u64) -> Failpoint {
        self.hits = Some(hits);
        self
    }
}

fn registry() -> &'static Mutex<HashMap<String, Failpoint>> {
    static REG: OnceLock<Mutex<HashMap<String, Failpoint>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(parse_env(&std::env::var("LEANVEC_FAILPOINTS").unwrap_or_default())))
}

fn lock() -> std::sync::MutexGuard<'static, HashMap<String, Failpoint>> {
    // a panic while holding this lock only poisons test bookkeeping;
    // the map itself is always in a consistent state between operations
    registry().lock().unwrap_or_else(PoisonError::into_inner)
}

/// Parse the `LEANVEC_FAILPOINTS` grammar; malformed entries are
/// dropped (fault injection must never take down a production start).
fn parse_env(spec: &str) -> HashMap<String, Failpoint> {
    let mut map = HashMap::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        if let Some((name, rest)) = entry.split_once('=') {
            if let Some(fp) = parse_one(rest) {
                map.insert(name.trim().to_string(), fp);
            }
        }
    }
    map
}

fn parse_one(rest: &str) -> Option<Failpoint> {
    // peel `#hits` then `@shard` suffixes, leaving `action[:arg]`
    let (rest, hits) = match rest.rsplit_once('#') {
        Some((head, h)) => (head, Some(h.parse::<u64>().ok()?)),
        None => (rest, None),
    };
    let (rest, shard) = match rest.rsplit_once('@') {
        Some((head, s)) => (head, Some(s.parse::<usize>().ok()?)),
        None => (rest, None),
    };
    let action = match rest.split_once(':') {
        Some(("sleep", ms)) => Action::Sleep(ms.parse().ok()?),
        None if rest == "panic" => Action::Panic,
        None if rest == "error" => Action::Error,
        _ => return None,
    };
    Some(Failpoint {
        action,
        shard,
        hits,
    })
}

/// Arm (or re-arm) a failpoint programmatically.
pub fn set(name: &str, fp: Failpoint) {
    lock().insert(name.to_string(), fp);
}

/// Disarm one failpoint.
pub fn clear(name: &str) {
    lock().remove(name);
}

/// Disarm everything (tests call this on exit so state never leaks
/// across the process-global registry).
pub fn clear_all() {
    lock().clear();
}

/// Evaluate the named failpoint at a call site.
///
/// `shard` is the caller's shard index when it has one. Sleeps happen
/// here; panics are raised here (the degraded-scatter machinery is
/// exactly what they exercise); an armed [`Action::Error`] is returned
/// for the caller to convert into its own error type. Returns `None`
/// when the point is unarmed, scoped to a different shard, or out of
/// hits.
pub fn hit(name: &str, shard: Option<usize>) -> Option<Action> {
    let action = {
        let mut map = lock();
        let fp = map.get_mut(name)?;
        if let (Some(want), Some(got)) = (fp.shard, shard) {
            if want != got {
                return None;
            }
        } else if fp.shard.is_some() && shard.is_none() {
            return None;
        }
        if let Some(hits) = &mut fp.hits {
            if *hits == 0 {
                return None;
            }
            *hits -= 1;
        }
        fp.action
    }; // registry lock released before sleeping/panicking
    match action {
        Action::Sleep(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            None
        }
        Action::Panic => panic!("failpoint {name} fired"),
        Action::Error => Some(Action::Error),
    }
}

/// Serialize tests that arm failpoints: the registry is process-global,
/// so concurrent tests would observe each other's points. Acquiring the
/// guard clears every armed point; callers should `clear_all()` (or
/// just drop the guard and let the next acquirer clear) when done.
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    let g = LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    clear_all();
    g
}

/// Poison a mutex from a helper thread (the `poison_lock` failpoint):
/// the serve path must tolerate a poisoned lock without losing queries,
/// and this gives chaos tests a deterministic way to produce one.
pub fn poison_mutex<T: Send>(lock: &std::sync::Mutex<T>) {
    let _ = std::thread::scope(|scope| {
        scope
            .spawn(|| {
                let _guard = lock.lock();
                panic!("failpoint poison_lock fired");
            })
            .join()
    });
    debug_assert!(lock.is_poisoned());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn env_grammar_parses_every_form() {
        let map = parse_env("slow_shard=sleep:50@1, panic_shard=panic@2#3,load=error,bad=nope");
        assert_eq!(
            map.get("slow_shard"),
            Some(&Failpoint::new(Action::Sleep(50)).on_shard(1))
        );
        assert_eq!(
            map.get("panic_shard"),
            Some(&Failpoint::new(Action::Panic).on_shard(2).times(3))
        );
        assert_eq!(map.get("load"), Some(&Failpoint::new(Action::Error)));
        assert!(!map.contains_key("bad"), "malformed entries are dropped");
    }

    #[test]
    fn unarmed_points_are_free() {
        let _g = guard();
        assert_eq!(hit("never_armed", None), None);
        assert_eq!(hit("never_armed", Some(3)), None);
    }

    #[test]
    fn shard_scope_restricts_firing() {
        let _g = guard();
        set("err", Failpoint::new(Action::Error).on_shard(1));
        assert_eq!(hit("err", Some(0)), None);
        assert_eq!(hit("err", None), None, "scoped points need a shard");
        assert_eq!(hit("err", Some(1)), Some(Action::Error));
        clear_all();
    }

    #[test]
    fn hit_budget_disarms() {
        let _g = guard();
        set("err", Failpoint::new(Action::Error).times(2));
        assert_eq!(hit("err", None), Some(Action::Error));
        assert_eq!(hit("err", Some(7)), Some(Action::Error));
        assert_eq!(hit("err", None), None, "out of hits");
        clear_all();
    }

    #[test]
    fn sleep_fires_inline_and_returns_none() {
        let _g = guard();
        set("nap", Failpoint::new(Action::Sleep(5)));
        let t0 = std::time::Instant::now();
        assert_eq!(hit("nap", None), None);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
        clear_all();
    }

    #[test]
    fn panic_action_panics_with_recognizable_message() {
        let _g = guard();
        set("boom", Failpoint::new(Action::Panic));
        let err = std::panic::catch_unwind(|| hit("boom", None)).unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("failpoint boom fired"), "got: {msg}");
        clear_all();
    }

    #[test]
    fn poison_mutex_poisons() {
        let m = Mutex::new(17);
        poison_mutex(&m);
        assert!(m.is_poisoned());
        // the data stays reachable through the poison
        assert_eq!(*m.lock().unwrap_or_else(PoisonError::into_inner), 17);
    }
}
