//! Explicit x86-64 kernels: AVX2 + FMA, plus F16C for the f16 path.
//!
//! Every public function here is a *safe-looking* wrapper around a
//! `#[target_feature]` inner function. The wrappers are `pub(super)`
//! and referenced **only** by the dispatcher in `simd::mod`, which
//! installs them exclusively after `is_x86_feature_detected!` confirmed
//! the features at process start — that detection is the safety
//! argument for every `unsafe` call in this file.
//!
//! Summation order differs from the scalar reference (wide lanes fold
//! at the end), so results agree with `simd::scalar` only to floating-
//! point tolerance, never bitwise — the parity property tests in
//! `rust/tests/score_decode.rs` pin that tolerance.

#![cfg(target_arch = "x86_64")]

use core::arch::x86_64::*;

/// Horizontal sum of one AVX register (SSE2-only shuffle sequence).
#[inline]
// SAFETY: `#[target_feature(enable = "avx2")]` makes this fn unsafe to
// call; the only callers are the kernels below, themselves gated on the
// same feature set by the dispatcher's runtime detection.
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    // SAFETY: register-only shuffles/adds — no memory access; AVX2 is
    // guaranteed by this fn's own `#[target_feature]` contract.
    unsafe {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps::<1>(v);
        let s = _mm_add_ps(lo, hi); // [a, b, c, d]
        let s2 = _mm_add_ps(s, _mm_movehl_ps(s, s)); // [a+c, b+d, ..]
        let s3 = _mm_add_ss(s2, _mm_shuffle_ps::<0x55>(s2, s2)); // + (b+d)
        _mm_cvtss_f32(s3)
    }
}

// SAFETY: unsafe-to-call by `#[target_feature]` contract only; callers
// (the wrappers below) run strictly behind avx2+fma runtime detection.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (pa, pb) = (a.as_ptr(), b.as_ptr());
    // SAFETY: every `loadu` reads 8 f32s at `p.add(i)` with
    // `i + 8 <= n` enforced by the loop bounds, so all reads stay
    // inside the borrowed slices (valid for `n` elements for the whole
    // call); `loadu` tolerates any alignment; the scalar tail uses
    // checked slice indexing.
    unsafe {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(pa.add(i + 8)),
                _mm256_loadu_ps(pb.add(i + 8)),
                acc1,
            );
            i += 16;
        }
        while i + 8 <= n {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += a[i] * b[i];
            i += 1;
        }
        sum
    }
}

// SAFETY: unsafe-to-call by `#[target_feature]` contract only; the
// dispatcher installs `dot_f16` solely when avx2+fma+f16c were all
// detected at startup.
#[target_feature(enable = "avx2", enable = "fma", enable = "f16c")]
unsafe fn dot_f16_f16c(codes: &[u16], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let n = codes.len();
    let (pc, pq) = (codes.as_ptr(), q.as_ptr());
    // SAFETY: each 128-bit load reads 8 u16 half floats at
    // `pc.add(i)` and each 256-bit load reads 8 f32s at `pq.add(i)`,
    // with `i + 8 <= n` (resp. `i + 16 <= n` for the unrolled pair)
    // enforced by the loop bounds — all reads stay inside the borrowed
    // slices; `loadu` variants have no alignment requirement; the tail
    // decodes with checked indexing.
    unsafe {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            let h0 = _mm_loadu_si128(pc.add(i) as *const __m128i);
            let h1 = _mm_loadu_si128(pc.add(i + 8) as *const __m128i);
            acc0 = _mm256_fmadd_ps(_mm256_cvtph_ps(h0), _mm256_loadu_ps(pq.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(_mm256_cvtph_ps(h1), _mm256_loadu_ps(pq.add(i + 8)), acc1);
            i += 16;
        }
        while i + 8 <= n {
            let h = _mm_loadu_si128(pc.add(i) as *const __m128i);
            acc0 = _mm256_fmadd_ps(_mm256_cvtph_ps(h), _mm256_loadu_ps(pq.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += crate::util::f16::f16_to_f32(codes[i]) * q[i];
            i += 1;
        }
        sum
    }
}

// SAFETY: unsafe-to-call by `#[target_feature]` contract only; callers
// run strictly behind avx2+fma runtime detection.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_u8_avx2(codes: &[u8], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let n = q.len();
    let (pc, pq) = (codes.as_ptr(), q.as_ptr());
    // SAFETY: the 16-wide body loads 16 code bytes + 16 f32s at offset
    // `i` with `i + 16 <= n`; the 8-wide body loads 8 bytes (64-bit
    // `loadl`) + 8 f32s with `i + 8 <= n`. `codes.len() == q.len() == n`
    // (debug-asserted, guaranteed by every store's row layout), so all
    // reads stay inside the borrowed slices; unaligned loads throughout.
    unsafe {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            // 16 u8 codes -> two u32x8 widens -> two f32x8 FMAs
            let c16 = _mm_loadu_si128(pc.add(i) as *const __m128i);
            let lo = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(c16));
            let hi = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(c16)));
            acc0 = _mm256_fmadd_ps(lo, _mm256_loadu_ps(pq.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(hi, _mm256_loadu_ps(pq.add(i + 8)), acc1);
            i += 16;
        }
        while i + 8 <= n {
            let c8 = _mm_loadl_epi64(pc.add(i) as *const __m128i);
            let cf = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(c8));
            acc0 = _mm256_fmadd_ps(cf, _mm256_loadu_ps(pq.add(i)), acc0);
            i += 8;
        }
        let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            sum += codes[i] as f32 * q[i];
            i += 1;
        }
        sum
    }
}

// SAFETY: unsafe-to-call by `#[target_feature]` contract only; callers
// run strictly behind avx2+fma runtime detection.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_u4_avx2(codes: &[u8], q: &[f32]) -> f32 {
    // two components per byte, low nibble first: byte j holds
    // components 2j (low) and 2j+1 (high)
    let n = q.len();
    debug_assert_eq!(codes.len(), n.div_ceil(2));
    let (pc, pq) = (codes.as_ptr(), q.as_ptr());
    // SAFETY: the body consumes 16 components per iteration: an 8-byte
    // `loadl` at `pc.add(i / 2)` (bytes i/2 .. i/2 + 8, in bounds since
    // `i + 16 <= n` implies `i/2 + 8 <= ceil(n/2) == codes.len()`) and
    // two 8-f32 `loadu`s at `pq.add(i)` / `pq.add(i + 8)`, in bounds by
    // the same loop guard. The nibble tail uses checked indexing.
    unsafe {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let nib_mask = _mm_set1_epi8(0x0F);
        let mut i = 0usize;
        while i + 16 <= n {
            // 8 packed bytes -> 16 nibbles, restored to component order
            // by interleaving the low- and high-nibble lanes
            let b = _mm_loadl_epi64(pc.add(i / 2) as *const __m128i);
            let lo_nib = _mm_and_si128(b, nib_mask);
            let hi_nib = _mm_and_si128(_mm_srli_epi16::<4>(b), nib_mask);
            let inter = _mm_unpacklo_epi8(lo_nib, hi_nib); // c[i..i+16]
            let c0 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(inter));
            let c1 = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(_mm_srli_si128::<8>(inter)));
            acc0 = _mm256_fmadd_ps(c0, _mm256_loadu_ps(pq.add(i)), acc0);
            acc1 = _mm256_fmadd_ps(c1, _mm256_loadu_ps(pq.add(i + 8)), acc1);
            i += 16;
        }
        let mut sum = hsum256(_mm256_add_ps(acc0, acc1));
        while i < n {
            let byte = codes[i / 2];
            let c = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            sum += c as f32 * q[i];
            i += 1;
        }
        sum
    }
}

// SAFETY: unsafe-to-call by `#[target_feature]` contract only; callers
// run strictly behind avx2+fma runtime detection.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_u4_u8_avx2(codes4: &[u8], codes8: &[u8], q: &[f32]) -> (f32, f32) {
    // SAFETY: both callees carry the same `#[target_feature]` set as
    // this fn, so the features are already guaranteed here; their slice
    // preconditions are forwarded unchanged.
    unsafe { (dot_u4_avx2(codes4, q), dot_u8_avx2(codes8, q)) }
}

// ---- dispatcher-facing wrappers -----------------------------------------
//
// All five wrappers exist to concentrate the feature-detection safety
// argument in one place: they are installed into the kernel table by
// `simd::select_kernels` only after `is_x86_feature_detected!`
// confirmed the required features on this host. Never call directly.

pub(super) fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: installed by the dispatcher only after avx2+fma were
    // detected at startup (see module header); never called directly.
    unsafe { dot_f32_avx2(a, b) }
}

pub(super) fn dot_f16(codes: &[u16], q: &[f32]) -> f32 {
    // SAFETY: installed by the dispatcher only after avx2+fma+f16c
    // were detected at startup; never called directly.
    unsafe { dot_f16_f16c(codes, q) }
}

pub(super) fn dot_u8(codes: &[u8], q: &[f32]) -> f32 {
    // SAFETY: installed by the dispatcher only after avx2+fma were
    // detected at startup; never called directly.
    unsafe { dot_u8_avx2(codes, q) }
}

pub(super) fn dot_u4(codes: &[u8], q: &[f32]) -> f32 {
    // SAFETY: installed by the dispatcher only after avx2+fma were
    // detected at startup; never called directly.
    unsafe { dot_u4_avx2(codes, q) }
}

pub(super) fn dot_u4_u8(codes4: &[u8], codes8: &[u8], q: &[f32]) -> (f32, f32) {
    // SAFETY: installed by the dispatcher only after avx2+fma were
    // detected at startup; never called directly.
    unsafe { dot_u4_u8_avx2(codes4, codes8, q) }
}
