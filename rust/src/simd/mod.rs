//! The kernel layer: fused decode+dot scoring kernels with one-time
//! runtime dispatch.
//!
//! Every score in the crate bottoms out in one of five kernels — f32
//! dot, fused f16 decode+dot, LVQ8 u8·f32, LVQ4 packed-nibble·f32, and
//! the LVQ4x8 residual combine. This module owns them:
//!
//! * [`scalar`] holds the portable reference implementations (the
//!   pre-SIMD loops, moved verbatim — bit-identical history).
//! * `x86` (x86-64 only) holds explicit `std::arch` implementations:
//!   AVX2 + FMA for the integer/float dots, plus F16C
//!   (`_mm256_cvtph_ps`) for the f16 path.
//! * The dispatcher picks a kernel set **once per process** via
//!   `is_x86_feature_detected!`, caches it in a `OnceLock`, and every
//!   call goes through a plain `fn` pointer — no per-call detection.
//!
//! Setting the environment variable `LEANVEC_FORCE_SCALAR=1` before
//! the first score pins the scalar set regardless of the host CPU:
//! determinism-sensitive tests and cross-machine comparisons get one
//! canonical answer ([`active_features`] reports what was picked).
//! On non-x86-64 targets the scalar set is the only set.
//!
//! How to add a kernel: put the portable loop in [`scalar`], the
//! `#[target_feature]` twin + safe wrapper in `x86`, add a `fn`-pointer
//! field to the internal table here, and extend the parity property
//! test in `rust/tests/score_decode.rs` (see
//! `docs/ARCHITECTURE.md` § "The kernel layer").

pub mod scalar;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::OnceLock;

/// The dispatched kernel set: one function pointer per kernel, selected
/// once at startup.
struct Kernels {
    dot_f32: fn(&[f32], &[f32]) -> f32,
    dot_f16: fn(&[u16], &[f32]) -> f32,
    dot_u8: fn(&[u8], &[f32]) -> f32,
    dot_u4: fn(&[u8], &[f32]) -> f32,
    dot_u4_u8: fn(&[u8], &[u8], &[f32]) -> (f32, f32),
    features: &'static str,
}

const SCALAR_KERNELS: Kernels = Kernels {
    dot_f32: scalar::dot_f32,
    dot_f16: scalar::dot_f16,
    dot_u8: scalar::dot_u8,
    dot_u4: scalar::dot_u4,
    dot_u4_u8: scalar::dot_u4_u8,
    features: "scalar",
};

/// Was `LEANVEC_FORCE_SCALAR` set (to anything but `0`/empty) when the
/// dispatcher first ran? Pinned for the process lifetime.
pub fn force_scalar_requested() -> bool {
    static FORCED: OnceLock<bool> = OnceLock::new();
    *FORCED.get_or_init(|| match std::env::var("LEANVEC_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    })
}

fn select_kernels() -> Kernels {
    if force_scalar_requested() {
        return Kernels {
            features: "scalar (LEANVEC_FORCE_SCALAR)",
            ..SCALAR_KERNELS
        };
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            let f16c = is_x86_feature_detected!("f16c");
            return Kernels {
                dot_f32: x86::dot_f32,
                // without F16C the f16 path alone stays scalar; the
                // other four kernels still dispatch to AVX2
                dot_f16: if f16c { x86::dot_f16 } else { scalar::dot_f16 },
                dot_u8: x86::dot_u8,
                dot_u4: x86::dot_u4,
                dot_u4_u8: x86::dot_u4_u8,
                features: if f16c { "avx2+fma+f16c" } else { "avx2+fma" },
            };
        }
    }
    SCALAR_KERNELS
}

#[inline]
fn kernels() -> &'static Kernels {
    static KERNELS: OnceLock<Kernels> = OnceLock::new();
    KERNELS.get_or_init(select_kernels)
}

/// Which kernel set the dispatcher picked for this process:
/// `"avx2+fma+f16c"`, `"avx2+fma"`, `"scalar"`, or
/// `"scalar (LEANVEC_FORCE_SCALAR)"`. Benches and the CI smoke step
/// print this so a silently-scalar host is visible in the log.
pub fn active_features() -> &'static str {
    kernels().features
}

/// f32 · f32 dot product.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    (kernels().dot_f32)(a, b)
}

/// Fused f16 decode + dot: `<decode(codes), q>` without materializing
/// the decoded vector.
#[inline]
pub fn dot_f16(codes: &[u16], q: &[f32]) -> f32 {
    (kernels().dot_f16)(codes, q)
}

/// u8 code · f32 query (the LVQ8 integer dot).
#[inline]
pub fn dot_u8(codes: &[u8], q: &[f32]) -> f32 {
    (kernels().dot_u8)(codes, q)
}

/// Packed-u4 code · f32 query (two components per byte, low nibble
/// first; the LVQ4 dot). `codes.len()` must be `ceil(q.len() / 2)`.
#[inline]
pub fn dot_u4(codes: &[u8], q: &[f32]) -> f32 {
    (kernels().dot_u4)(codes, q)
}

/// LVQ4x8 residual combine: `(dot_u4(codes4, q), dot_u8(codes8, q))`
/// in one call — the two-level re-rank score reads both levels of one
/// vector against the same query.
#[inline]
pub fn dot_u4_u8(codes4: &[u8], codes8: &[u8], q: &[f32]) -> (f32, f32) {
    (kernels().dot_u4_u8)(codes4, codes8, q)
}

/// Software prefetch (to all cache levels) of the cache line at the
/// start of `data` — the blocked scoring paths issue this for the
/// *next* row's code bytes while the current row computes. No-op on
/// non-x86-64 targets and for empty slices' dangling base pointers
/// (prefetch is a hint; it never faults).
#[inline(always)]
pub fn prefetch<T>(data: &[T]) {
    // SAFETY: `_mm_prefetch` is a pure cache hint in x86-64's baseline
    // (SSE) set: it never faults, even on a dangling empty-slice base
    // pointer, and reads or writes no memory architecturally.
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<{ _MM_HINT_T0 }>(data.as_ptr() as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = data;
}

/// [`prefetch`] for a whole bounded row: one prefetch per 64-byte
/// cache line over the slice, so a multi-line code row (e.g. a
/// 768-dim f16 row is 24 lines) is fully in flight before the scoring
/// kernel touches it. Beam search uses this for the *next hop's*
/// neighbor rows, which on an mmap-served index overlaps resident
/// page-cache line fills with the current hop's compute.
#[inline]
pub fn prefetch_row<T>(data: &[T]) {
    // SAFETY: prefetch is a non-faulting hint (see `prefetch`); the
    // `ptr.add(off)` addresses stay within `size_of_val(data)` bytes of
    // the slice base by the loop bound, and even a stale address could
    // at worst warm the wrong line.
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let bytes = std::mem::size_of_val(data);
        let ptr = data.as_ptr() as *const i8;
        let mut off = 0usize;
        while off < bytes {
            _mm_prefetch::<{ _MM_HINT_T0 }>(ptr.add(off));
            off += 64;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = data;
}

#[cfg(test)]
mod tests {
    // Scalar-vs-dispatched numeric parity lives in ONE place —
    // `rust/tests/score_decode.rs::kernel_parity_scalar_vs_dispatched_awkward_dims`
    // — so the tolerance and dim list cannot drift between copies.
    // Here we only pin the dispatch mechanics themselves.
    use super::*;

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn dispatch_is_stable_and_named() {
        let a = active_features();
        let b = active_features();
        assert_eq!(a, b, "dispatch must be pinned per process");
        assert!(!a.is_empty());
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn prefetch_accepts_any_slice() {
        let v = vec![1u8, 2, 3];
        prefetch(&v);
        let f = vec![1.0f32];
        prefetch(&f);
        let empty: &[u16] = &[];
        prefetch(empty);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn prefetch_row_spans_lines_and_accepts_empty() {
        let big = vec![0u8; 1000]; // 16 cache lines
        prefetch_row(&big);
        let f = vec![1.0f32; 200];
        prefetch_row(&f);
        let empty: &[u32] = &[];
        prefetch_row(empty);
    }
}
