//! Portable scalar reference kernels.
//!
//! These are the pre-SIMD scoring loops, moved here **verbatim** from
//! `linalg::matrix` (f32 dot), `quant::stores` (the f16 table loop) and
//! `quant::lvq` (the u8/u4 code dots): same unrolling, same summation
//! order, same tail handling. That is a hard contract — when the
//! dispatcher pins the scalar set (`LEANVEC_FORCE_SCALAR=1`, or a host
//! without AVX2), every score in the crate is bit-identical to what it
//! was before the kernel layer existed, which is what the snapshot
//! bit-identity tests certify.
//!
//! They are also the parity oracle: `rust/tests/score_decode.rs`
//! compares every dispatched kernel against these on awkward shapes.

/// f32 · f32 with 8-way unrolling (the historical `linalg::matrix::dot`
/// body; autovectorizes reasonably, which is why it was the baseline).
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        s4 += a[i + 4] * b[i + 4];
        s5 += a[i + 5] * b[i + 5];
        s6 += a[i + 6] * b[i + 6];
        s7 += a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    (s0 + s4) + (s1 + s5) + (s2 + s6) + (s3 + s7) + tail
}

/// Fused f16 decode + dot via the 64K decode table (the historical
/// `F16Store::score` inner loop) — no temporaries, 4-way unrolled.
pub fn dot_f16(codes: &[u16], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let table = crate::util::f16::decode_table();
    let n = codes.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += table[codes[i] as usize] * q[i];
        s1 += table[codes[i + 1] as usize] * q[i + 1];
        s2 += table[codes[i + 2] as usize] * q[i + 2];
        s3 += table[codes[i + 3] as usize] * q[i + 3];
    }
    let mut ip = (s0 + s1) + (s2 + s3);
    for i in chunks * 4..n {
        ip += table[codes[i] as usize] * q[i];
    }
    ip
}

/// u8 code · f32 query with 4-way unrolling (the historical LVQ8
/// `code_dot_u8`).
pub fn dot_u8(codes: &[u8], q: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), q.len());
    let n = q.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += codes[i] as f32 * q[i];
        s1 += codes[i + 1] as f32 * q[i + 1];
        s2 += codes[i + 2] as f32 * q[i + 2];
        s3 += codes[i + 3] as f32 * q[i + 3];
    }
    let mut tail = 0.0f32;
    for i in chunks * 4..n {
        tail += codes[i] as f32 * q[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// packed-u4 code · f32 query (two components per byte, low nibble
/// first; the historical LVQ4 `code_dot_u4`). `codes.len()` is
/// `ceil(q.len() / 2)`.
pub fn dot_u4(codes: &[u8], q: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    let n = q.len();
    for (b, byte) in codes.iter().enumerate() {
        let i = b * 2;
        acc += (byte & 0x0F) as f32 * q[i];
        if i + 1 < n {
            acc += (byte >> 4) as f32 * q[i + 1];
        }
    }
    acc
}

/// LVQ4x8 residual combine: the 4-bit primary dot and the 8-bit
/// residual dot of one two-level vector against the same query,
/// computed exactly as two sequential scalar dots (the historical
/// `Lvq4x8Store::score_full` order).
pub fn dot_u4_u8(codes4: &[u8], codes8: &[u8], q: &[f32]) -> (f32, f32) {
    (dot_u4(codes4, q), dot_u8(codes8, q))
}
