//! Streaming mutation: the live index subsystem.
//!
//! Everything upstream of this module is frozen-at-build; this module
//! makes the serve path mutable — FreshDiskANN-style streaming inserts
//! and deletes running *concurrently with search*, plus the
//! consolidation pass that compacts tombstones away:
//!
//! ```text
//! insert(ext_id, x) ──> project B x ──> append to both stores ──┐
//!                                                               ▼
//!                      greedy-search + α-robust-prune link, reverse-edge patch
//! delete(ext_id)  ──> tombstone bit (O(1)); traversal routes through,
//!                      never returns ([`QueryStats::deleted_skipped`])
//! consolidate()   ──> rewire neighbors-of-deleted, compact stores +
//!                      graph + id map, clear tombstones
//! ```
//!
//! The module splits into:
//! * [`live`] — [`LiveIndex`], the mutable index and its search path;
//! * [`adjacency`] — the RwLock-sharded growable neighbor lists;
//! * [`tombstones`] — the lock-free-readable deletion bitmap;
//! * [`persist_live`] — live snapshot save/load
//!   (`FORMAT_VERSION_LIVE`, `TOMBS`/`IDMAP`/`MUTLOG` sections).
//!
//! The serving engine drives it through an ingest lane
//! ([`crate::coordinator::Engine::start_live`]): one mutation thread
//! interleaved with the search worker pool, consolidation triggered off
//! the hot path when the tombstone fraction crosses a threshold.
//!
//! [`QueryStats::deleted_skipped`]: crate::index::query::QueryStats

pub mod adjacency;
pub mod live;
pub mod persist_live;
pub mod tombstones;

pub use adjacency::{AdjacencyReader, LiveAdjacency};
pub use live::{ConsolidateReport, LiveIndex, MutateError, MutationJournal};
pub use tombstones::{TombstoneReader, Tombstones};
