//! Tombstone bitmap for the live index: deletes are O(1) bit-sets
//! honored by traversal (routed *through*, never returned) until a
//! consolidation pass compacts them away.
//!
//! Concurrency contract (the whole `mutate` module shares it): **one
//! writer, many readers**. Mutators are serialized by
//! [`crate::mutate::LiveIndex`]'s writer lock; searches read through a
//! [`TombstoneReader`] snapshot taken once per query and never block —
//! bit tests are relaxed atomic loads on a shared word array. Growth
//! (the only structural change) copies the words into a larger array
//! and swaps the `Arc`, so an in-flight reader keeps a consistent view
//! of the bitmap as it was when its query started.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Grow-only atomic bitmap + deleted counter.
pub struct Tombstones {
    words: RwLock<Arc<Vec<AtomicU64>>>,
    deleted: AtomicUsize,
}

/// A per-query snapshot of the bitmap: lock-free bit tests.
#[derive(Clone)]
pub struct TombstoneReader {
    words: Arc<Vec<AtomicU64>>,
}

impl TombstoneReader {
    /// Is `id` tombstoned? Ids beyond the snapshot are alive by
    /// definition (they were inserted after it was taken).
    #[inline]
    pub fn is_deleted(&self, id: u32) -> bool {
        let w = id as usize / 64;
        match self.words.get(w) {
            // ORDERING: Relaxed — the bit itself is the entire payload;
            // traversal tolerates observing a delete late (the row is
            // filtered on a later query) and there is no other data
            // whose visibility this load must order.
            Some(word) => (word.load(Ordering::Relaxed) >> (id % 64)) & 1 == 1,
            None => false,
        }
    }
}

fn new_words(capacity: usize) -> Vec<AtomicU64> {
    (0..capacity.div_ceil(64)).map(|_| AtomicU64::new(0)).collect()
}

impl Tombstones {
    /// An all-alive bitmap covering `capacity` ids.
    pub fn new(capacity: usize) -> Tombstones {
        Tombstones {
            words: RwLock::new(Arc::new(new_words(capacity))),
            deleted: AtomicUsize::new(0),
        }
    }

    /// Rebuild from persisted words (see `mutate::persist_live`).
    pub fn from_words(words: &[u64], capacity: usize) -> Tombstones {
        let vec = new_words(capacity.max(words.len() * 64));
        let mut deleted = 0usize;
        for (slot, &w) in vec.iter().zip(words.iter()) {
            // ORDERING: Relaxed — single-threaded construction; the
            // value is published to other threads by moving the whole
            // struct afterwards.
            slot.store(w, Ordering::Relaxed);
            deleted += w.count_ones() as usize;
        }
        Tombstones {
            words: RwLock::new(Arc::new(vec)),
            deleted: AtomicUsize::new(deleted),
        }
    }

    /// Snapshot for one query's traversal.
    pub fn reader(&self) -> TombstoneReader {
        TombstoneReader {
            // a poisoned lock only means another thread panicked while
            // holding it; the bitmap itself is atomics and stays valid,
            // so serve traffic reads through the poison
            words: Arc::clone(
                &self
                    .words
                    .read()
                    .unwrap_or_else(std::sync::PoisonError::into_inner),
            ),
        }
    }

    /// Grow to cover at least `n` ids (writer-side; called on insert).
    pub fn ensure(&self, n: usize) {
        let need = n.div_ceil(64);
        {
            let cur = self
                .words
                .read()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            if cur.len() >= need {
                return;
            }
        }
        let mut guard = self
            .words
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        if guard.len() >= need {
            return;
        }
        // grow with slack so the copy amortizes across inserts
        let grown = new_words((need * 64).max(guard.len() * 2 * 64));
        for (dst, src) in grown.iter().zip(guard.iter()) {
            // ORDERING: Relaxed — the copy runs under the exclusive
            // write lock (mutators are also serialized by the writer
            // lock above this layer); readers see the grown array only
            // through the RwLock's release/acquire on the Arc swap.
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        *guard = Arc::new(grown);
    }

    /// Tombstone `id`; returns false if it was already set. The caller
    /// must have `ensure`d capacity (every insert does).
    pub fn set(&self, id: u32) -> bool {
        let guard = self
            .words
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        let w = id as usize / 64;
        let bit = 1u64 << (id % 64);
        // ORDERING: Relaxed — the bit is the payload (see `is_deleted`);
        // the RMW's atomicity alone guarantees exactly one caller wins
        // a concurrent double-delete race.
        let prev = guard[w].fetch_or(bit, Ordering::Relaxed);
        if prev & bit == 0 {
            // ORDERING: Relaxed — statistics counter; read for consolidation
            // scheduling and reporting, never to guard data.
            self.deleted.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Is `id` currently tombstoned?
    pub fn is_deleted(&self, id: u32) -> bool {
        self.reader().is_deleted(id)
    }

    /// Number of tombstoned ids.
    pub fn deleted(&self) -> usize {
        // ORDERING: Relaxed — statistics counter (see `set`).
        self.deleted.load(Ordering::Relaxed)
    }

    /// Reset to all-alive over `capacity` ids (after consolidation).
    pub fn reset(&self, capacity: usize) {
        let mut guard = self
            .words
            .write()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        *guard = Arc::new(new_words(capacity));
        // ORDERING: Relaxed — statistics counter; the fresh bitmap is
        // published by the RwLock release above it.
        self.deleted.store(0, Ordering::Relaxed);
    }

    /// Plain-word image for persistence.
    pub fn to_words(&self) -> Vec<u64> {
        self.words
            .read()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .iter()
            // ORDERING: Relaxed — persistence runs on the writer path
            // with mutators quiesced by the writer lock; bits only ever
            // set monotonically, so a racing reader image is still a
            // valid (slightly stale) snapshot.
            .map(|w| w.load(Ordering::Relaxed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_and_test_bits() {
        let t = Tombstones::new(130);
        assert!(!t.is_deleted(0));
        assert!(t.set(0));
        assert!(!t.set(0), "double delete is idempotent");
        assert!(t.set(129));
        assert!(t.is_deleted(0));
        assert!(t.is_deleted(129));
        assert!(!t.is_deleted(64));
        assert_eq!(t.deleted(), 2);
    }

    #[test]
    fn reader_snapshot_is_stable_across_growth() {
        let t = Tombstones::new(64);
        t.set(3);
        let snap = t.reader();
        t.ensure(1024);
        t.set(700);
        // the old snapshot still sees id 3 deleted and treats the new
        // range as alive
        assert!(snap.is_deleted(3));
        assert!(!snap.is_deleted(700));
        assert!(t.is_deleted(700));
        assert!(t.is_deleted(3), "growth copies existing bits");
    }

    #[test]
    fn reset_clears_everything() {
        let t = Tombstones::new(64);
        t.set(1);
        t.set(2);
        t.reset(128);
        assert_eq!(t.deleted(), 0);
        assert!(!t.is_deleted(1));
    }

    #[test]
    fn words_roundtrip() {
        let t = Tombstones::new(200);
        t.set(5);
        t.set(70);
        t.set(199);
        let back = Tombstones::from_words(&t.to_words(), 200);
        assert_eq!(back.deleted(), 3);
        for id in [5u32, 70, 199] {
            assert!(back.is_deleted(id));
        }
        assert!(!back.is_deleted(6));
    }
}
