//! [`LiveIndex`]: a [`LeanVecIndex`] that accepts streaming inserts and
//! deletes while serving searches, FreshDiskANN-style.
//!
//! * **insert** projects the new vector through the frozen LeanVec
//!   model (`B x`), LVQ-encodes it into the primary store with the
//!   store's existing constants, appends the full-dimensional vector to
//!   the secondary store, then links the node into the Vamana graph via
//!   greedy search + α-robust-prune with reverse-edge patching — the
//!   same rule the batch builder applies, shared through
//!   [`crate::graph::vamana::robust_prune`].
//! * **delete** is an O(1) tombstone: traversal routes *through*
//!   tombstoned nodes (connectivity is preserved — the PR 3 filtered
//!   search machinery) but never returns them;
//!   [`QueryStats::deleted_skipped`] counts them per query.
//! * **consolidate** rewires every neighbor-of-a-deleted edge
//!   (pool = live neighbors ∪ live neighbors-of-deleted-neighbors,
//!   re-pruned), then compacts the stores, graph, and id map,
//!   clearing the tombstones.
//!
//! # Concurrency
//!
//! One writer, many readers. Mutators serialize on an internal writer
//! lock (the engine's ingest lane is one thread anyway); searches never
//! take it. The query path takes a *read* guard on the store core for
//! the duration of one search — concurrent searches share it freely —
//! plus per-shard graph locks and a lock-free tombstone snapshot, so
//! searches run concurrently with each other and with mutations.
//! Inserts hold the core write guard only for the O(dim) store append;
//! graph linking runs under a read guard. The only stop-the-world
//! moment is the compaction half of [`LiveIndex::consolidate`] (the
//! expensive rewiring half runs under a read guard).
//!
//! # External ids
//!
//! Compaction renumbers internal slots, so the index speaks *external*
//! ids at its edge: [`LiveIndex::insert`] takes the caller's id,
//! searches return external ids, [`LiveIndex::delete`] takes one.
//! An index thawed from a built [`LeanVecIndex`] starts with external
//! id `i` == internal slot `i`.
//!
//! [`QueryStats::deleted_skipped`]: crate::index::query::QueryStats

use crate::config::{Compression, GraphParams, Similarity};
use crate::graph::beam::{greedy_search_ext, SearchCtx};
use crate::graph::vamana::{medoid_of, robust_prune, Adjacency};
use crate::index::leanvec_index::{BuildBreakdown, LeanVecIndex, SearchParams};
use crate::index::query::{Query, QueryStats, SearchResult, VectorIndex};
use crate::leanvec::model::LeanVecModel;
use crate::mutate::adjacency::LiveAdjacency;
use crate::mutate::tombstones::Tombstones;
use crate::quant::ScoreStore;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Everything that can go wrong mutating a live index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MutateError {
    /// The external id is already live.
    DuplicateId(u32),
    /// The external id is not live (never inserted, or already deleted).
    UnknownId(u32),
    /// The vector's dimensionality does not match the index.
    DimMismatch { expected: usize, got: usize },
    /// The vector contains NaN or infinite components (they would
    /// poison the distance-based prune rule).
    NonFinite,
    /// The target index holds no live shards — it was built or loaded
    /// frozen ([`crate::shard::ShardedIndex`] routes mutations only when
    /// its shards are [`LiveIndex`]es).
    Frozen,
}

impl std::fmt::Display for MutateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MutateError::DuplicateId(id) => write!(f, "insert: id {id} is already live"),
            MutateError::UnknownId(id) => write!(f, "delete: id {id} is not live"),
            MutateError::DimMismatch { expected, got } => {
                write!(f, "vector has {got} dims, index expects {expected}")
            }
            MutateError::NonFinite => {
                write!(f, "insert: vector has NaN or infinite components")
            }
            MutateError::Frozen => {
                write!(f, "index is frozen (no live shards accept mutations)")
            }
        }
    }
}

impl std::error::Error for MutateError {}

/// Lifetime mutation counters; survive snapshots (observability).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MutationJournal {
    pub inserts: u64,
    pub deletes: u64,
    pub consolidations: u64,
}

/// What one [`LiveIndex::consolidate`] pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConsolidateReport {
    /// tombstoned slots removed by compaction
    pub removed: usize,
    /// live nodes whose edges were rewired around deleted neighbors
    pub rewired: usize,
    /// live nodes remaining after compaction
    pub remaining: usize,
    /// wall-clock seconds for the whole pass
    pub seconds: f64,
}

/// The mutable core: both stores plus the external↔internal id maps and
/// the insert journal, all swapped/compacted together under one lock so
/// a search can never observe them out of step.
pub(crate) struct Core {
    pub(crate) primary: Box<dyn ScoreStore>,
    pub(crate) secondary: Box<dyn ScoreStore>,
    /// internal slot -> external id
    pub(crate) ext_of: Vec<u32>,
    /// external id -> internal slot (live ids only)
    pub(crate) int_of: HashMap<u32, u32>,
    /// (external id, full-D vector) of every insert since the last
    /// consolidation — the snapshot insert log, and the feed a future
    /// model re-train would consume (data drift)
    pub(crate) insert_log: Vec<(u32, Vec<f32>)>,
    pub(crate) journal: MutationJournal,
}

/// A live (streaming-mutable) LeanVec index. Construct with
/// [`LiveIndex::from_index`] or load a live snapshot with
/// [`LiveIndex::load`] (`mutate::persist_live`).
pub struct LiveIndex {
    pub(crate) model: LeanVecModel,
    pub(crate) sim: Similarity,
    pub(crate) primary_compression: Compression,
    pub(crate) secondary_compression: Compression,
    pub(crate) params: GraphParams,
    pub(crate) build_breakdown: BuildBreakdown,
    pub(crate) graph_build_seconds: f64,
    pub(crate) core: RwLock<Core>,
    pub(crate) graph: LiveAdjacency,
    pub(crate) medoid: AtomicU32,
    pub(crate) tombs: Tombstones,
    /// serializes insert/delete/consolidate/save (single-writer
    /// discipline; the engine's ingest lane is one thread)
    pub(crate) writer: Mutex<()>,
    /// reusable traversal state for the insert link phase — mutators
    /// are serialized, so one pooled context suffices and inserts never
    /// re-allocate the O(n) visited array
    link_ctx: Mutex<SearchCtx>,
}

impl LiveIndex {
    /// Thaw a built (or snapshot-loaded) index into a live one.
    /// External ids start equal to the build positions `0..n`.
    pub fn from_index(index: LeanVecIndex) -> LiveIndex {
        let LeanVecIndex {
            model,
            primary,
            secondary,
            graph,
            sim,
            primary_compression,
            secondary_compression,
            build_breakdown,
            // safe to drop: each mapped array holds its own handle on
            // the mapping, and mutation converts arrays to owned
            backing: _,
        } = index;
        let n = primary.len();
        LiveIndex {
            model,
            sim,
            primary_compression,
            secondary_compression,
            params: graph.params,
            build_breakdown,
            graph_build_seconds: graph.build_seconds,
            graph: LiveAdjacency::from_adjacency(&graph.adj),
            medoid: AtomicU32::new(graph.medoid),
            tombs: Tombstones::new(n),
            core: RwLock::new(Core {
                primary,
                secondary,
                ext_of: (0..n as u32).collect(),
                int_of: (0..n as u32).map(|i| (i, i)).collect(),
                insert_log: Vec::new(),
                journal: MutationJournal::default(),
            }),
            writer: Mutex::new(()),
            link_ctx: Mutex::new(SearchCtx::new(n)),
        }
    }

    /// [`LiveIndex::from_index`] with an explicit external-id map:
    /// internal slot `i` serves (and is addressed by) `ext_ids[i]`. The
    /// sharded layer thaws each shard with the global ids of the rows it
    /// was built over, so inserts/deletes route by external id and
    /// results come back in the caller's namespace.
    ///
    /// Panics if `ext_ids` does not cover the index (one id per row) or
    /// repeats an id.
    pub fn from_index_with_ids(index: LeanVecIndex, ext_ids: Vec<u32>) -> LiveIndex {
        let live = LiveIndex::from_index(index);
        {
            let mut core = live.core_write();
            assert_eq!(
                ext_ids.len(),
                core.ext_of.len(),
                "external-id map must cover every row"
            );
            let int_of: HashMap<u32, u32> = ext_ids
                .iter()
                .enumerate()
                .map(|(i, &e)| (e, i as u32))
                .collect();
            assert_eq!(int_of.len(), ext_ids.len(), "external ids must be unique");
            core.ext_of = ext_ids;
            core.int_of = int_of;
        }
        live
    }

    // Poisoned locks are recovered (`PoisonError::into_inner`) rather
    // than propagated: the core/writer/link-ctx critical sections keep
    // their data structurally valid at every line, so a panicking peer
    // leaves consistent state behind and searches should keep serving.
    pub(crate) fn core_read(&self) -> RwLockReadGuard<'_, Core> {
        self.core.read().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    pub(crate) fn core_write(&self) -> RwLockWriteGuard<'_, Core> {
        self.core.write().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Total node slots (live + tombstoned).
    pub fn total_slots(&self) -> usize {
        self.graph.len()
    }

    /// Test-battery hook: plant a bogus external→internal mapping so
    /// the fsck bijection checker has an idmap corruption (unreachable
    /// through `insert`/`delete`, which keep the two maps in lockstep
    /// under the writer lock) to detect.
    #[doc(hidden)]
    pub fn corrupt_idmap_for_fsck(&self, ext_id: u32, bogus_slot: u32) {
        self.core_write().int_of.insert(ext_id, bogus_slot);
    }

    /// Deep consistency check for the fsck layer: store/graph/idmap
    /// row counts agree, both stores' internal invariants hold, store
    /// dims match the projection model, the live adjacency is
    /// structurally sound, the medoid names a real slot, the tombstone
    /// bitmap covers every slot with its deleted counter in agreement,
    /// the ext↔int id maps are a bijection over the live slots, and the
    /// insert log stays within bounds. Returns a typed report instead
    /// of panicking; `repro fsck` and the corruption battery share it.
    pub fn check_invariants(&self) -> crate::util::invariants::FsckReport {
        use crate::util::invariants::{FsckReport, Violation};
        let mut report = FsckReport::default();
        let _writer = self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let core = self.core_read();
        let total = self.graph.len();
        if core.primary.len() != total
            || core.secondary.len() != total
            || core.ext_of.len() != total
        {
            report.violations.push(Violation::new(
                "live-index",
                "store-len-mismatch",
                format!(
                    "primary {} / secondary {} / ext_of {} disagree with {total} graph slots",
                    core.primary.len(),
                    core.secondary.len(),
                    core.ext_of.len()
                ),
            ));
        }
        if core.primary.dim() != self.model.target_dim() {
            report.violations.push(Violation::new(
                "live-index",
                "dim-mismatch",
                format!(
                    "primary store dim {} != model target dim {}",
                    core.primary.dim(),
                    self.model.target_dim()
                ),
            ));
        }
        if core.secondary.dim() != self.model.input_dim() {
            report.violations.push(Violation::new(
                "live-index",
                "dim-mismatch",
                format!(
                    "secondary store dim {} != model input dim {}",
                    core.secondary.dim(),
                    self.model.input_dim()
                ),
            ));
        }
        for (layer, store) in [
            ("primary-store", &core.primary),
            ("secondary-store", &core.secondary),
        ] {
            let mut tmp = Vec::new();
            store.check_invariants(&mut tmp);
            for mut v in tmp {
                v.layer = layer;
                report.violations.push(v);
            }
            report
                .checked
                .push(format!("{layer}: {} rows x {} dims", store.len(), store.dim()));
        }
        self.graph.check_invariants(&mut report.violations);
        let medoid = self.medoid.load(Ordering::Acquire);
        if total > 0 && medoid as usize >= total {
            report.violations.push(Violation::new(
                "graph",
                "medoid-out-of-range",
                format!("medoid {medoid} >= {total} slots"),
            ));
        }

        // tombstone bitmap: covers every slot, no bits past the end,
        // and the O(1) deleted counter agrees with the actual bits
        let words = self.tombs.to_words();
        let deleted = self.tombs.deleted();
        if words.len() * 64 < total {
            report.violations.push(Violation::new(
                "live-index",
                "tombstone-bitmap",
                format!("bitmap covers {} ids, {total} slots exist", words.len() * 64),
            ));
        } else {
            let mut popcount = 0usize;
            let mut stray = false;
            for (w, &word) in words.iter().enumerate() {
                for b in 0..64 {
                    if (word >> b) & 1 == 1 {
                        if w * 64 + b < total {
                            popcount += 1;
                        } else {
                            stray = true;
                        }
                    }
                }
            }
            if stray {
                report.violations.push(Violation::new(
                    "live-index",
                    "tombstone-bitmap",
                    format!("bit set past the last slot ({total} slots)"),
                ));
            }
            if popcount != deleted {
                report.violations.push(Violation::new(
                    "live-index",
                    "tombstone-bitmap",
                    format!("{popcount} bits set, deleted counter says {deleted}"),
                ));
            }
        }

        // ext↔int bijection over the live slots, both directions
        let tomb = self.tombs.reader();
        let live_slots = total.saturating_sub(deleted);
        if core.int_of.len() != live_slots {
            report.violations.push(Violation::new(
                "live-index",
                "idmap-not-bijective",
                format!(
                    "{} forward mappings for {live_slots} live slots",
                    core.int_of.len()
                ),
            ));
        }
        let mut samples = 0;
        for (&ext, &int) in core.int_of.iter() {
            let bad = match core.ext_of.get(int as usize) {
                None => Some(format!("ext {ext} -> slot {int} out of range")),
                Some(&back) if back != ext => Some(format!(
                    "ext {ext} -> slot {int}, but slot maps back to ext {back}"
                )),
                Some(_) if tomb.is_deleted(int) => {
                    Some(format!("ext {ext} -> slot {int}, which is tombstoned"))
                }
                Some(_) => None,
            };
            if let Some(detail) = bad {
                report.violations.push(Violation::new(
                    "live-index",
                    "idmap-not-bijective",
                    detail,
                ));
                samples += 1;
                if samples >= 16 {
                    break;
                }
            }
        }

        // insert log: bounded by the slots consumed since the last
        // consolidation, every logged vector full-dimensional
        if core.insert_log.len() > total {
            report.violations.push(Violation::new(
                "live-index",
                "insert-log-bounds",
                format!(
                    "{} logged inserts for {total} total slots",
                    core.insert_log.len()
                ),
            ));
        }
        if let Some((ext, v)) = core
            .insert_log
            .iter()
            .find(|(_, v)| v.len() != self.model.input_dim())
        {
            report.violations.push(Violation::new(
                "live-index",
                "insert-log-bounds",
                format!(
                    "logged insert {ext} has {} dims, model wants {}",
                    v.len(),
                    self.model.input_dim()
                ),
            ));
        }
        report.checked.push(format!(
            "live graph: {total} slots ({live_slots} live, {deleted} tombstoned), \
             max degree {}, insert log {}",
            self.graph.max_degree(),
            core.insert_log.len()
        ));
        report
    }

    /// Number of live (searchable) vectors.
    pub fn live_len(&self) -> usize {
        self.graph.len().saturating_sub(self.tombs.deleted())
    }

    /// Fraction of slots that are tombstoned — the consolidation
    /// trigger the engine's ingest lane watches.
    pub fn tombstone_fraction(&self) -> f64 {
        let n = self.graph.len();
        if n == 0 {
            0.0
        } else {
            self.tombs.deleted() as f64 / n as f64
        }
    }

    /// Inserts not yet folded into a consolidation (insert-log length).
    pub fn pending_inserts(&self) -> usize {
        self.core_read().insert_log.len()
    }

    /// Lifetime mutation counters.
    pub fn journal(&self) -> MutationJournal {
        self.core_read().journal
    }

    pub fn graph_params(&self) -> GraphParams {
        self.params
    }

    pub fn similarity(&self) -> Similarity {
        self.sim
    }

    /// The frozen LeanVec projection model (queries go through `A q`).
    pub fn model(&self) -> &LeanVecModel {
        &self.model
    }

    /// Is `ext_id` currently live?
    pub fn contains(&self, ext_id: u32) -> bool {
        self.core_read().int_of.contains_key(&ext_id)
    }

    /// The external ids currently live, in internal-slot order.
    pub fn live_ids(&self) -> Vec<u32> {
        let core = self.core_read();
        let n = self.graph.len().min(core.primary.len());
        let tomb = self.tombs.reader();
        (0..n as u32)
            .filter(|&id| !tomb.is_deleted(id))
            .map(|id| core.ext_of[id as usize])
            .collect()
    }

    /// The live id set with full-dimensional vectors (secondary-store
    /// decodes) — the exact corpus a flat oracle over the live set
    /// scores against.
    pub fn export_live(&self) -> Vec<(u32, Vec<f32>)> {
        let core = self.core_read();
        let n = self.graph.len().min(core.primary.len());
        let tomb = self.tombs.reader();
        (0..n as u32)
            .filter(|&id| !tomb.is_deleted(id))
            .map(|id| (core.ext_of[id as usize], core.secondary.decode(id)))
            .collect()
    }

    /// Insert `vector` under the caller's `ext_id`. Returns the internal
    /// slot (diagnostics only — slots are renumbered by consolidation).
    /// Errors if `ext_id` is already live or the dimensionality is
    /// wrong. Searches run concurrently throughout.
    pub fn insert(&self, ext_id: u32, vector: &[f32]) -> Result<u32, MutateError> {
        if vector.len() != self.model.input_dim() {
            return Err(MutateError::DimMismatch {
                expected: self.model.input_dim(),
                got: vector.len(),
            });
        }
        if !vector.iter().all(|v| v.is_finite()) {
            return Err(MutateError::NonFinite);
        }
        let _writer = self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        // duplicate check before the projection matmul: only mutators
        // (serialized by the writer lock we hold) touch `int_of`, so a
        // cheap read here is authoritative and rejected replays never
        // pay the O(D*d) projection
        if self.core_read().int_of.contains_key(&ext_id) {
            return Err(MutateError::DuplicateId(ext_id));
        }
        let proj = self.model.project_database_vector(vector);
        let id = {
            let mut core = self.core_write();
            debug_assert!(!core.int_of.contains_key(&ext_id));
            let id = core.primary.len() as u32;
            core.primary.append_row(&proj);
            core.secondary.append_row(vector);
            core.ext_of.push(ext_id);
            core.int_of.insert(ext_id, id);
            core.insert_log.push((ext_id, vector.to_vec()));
            core.journal.inserts += 1;
            id
        };
        self.tombs.ensure(id as usize + 1);
        let slot = self.graph.add_node();
        debug_assert_eq!(slot, id);
        // link under a read guard: searches continue while we wire edges
        let core = self.core_read();
        self.link_node(&core, id, &proj);
        Ok(id)
    }

    /// Greedy-search + α-robust-prune linking of a freshly appended
    /// node, with reverse-edge patching (overflowing reverse lists are
    /// re-pruned) — the builder's insertion rule, applied online.
    fn link_node(&self, core: &Core, id: u32, proj: &[f32]) {
        let store = core.primary.as_ref();
        let medoid = self.medoid.load(Ordering::Acquire);
        if medoid == id {
            return; // degenerate single-node graph
        }
        let pq = store.prepare(proj, self.sim);
        let reader = self.graph.reader();
        let tomb = self.tombs.reader();
        let mut ctx = self.link_ctx.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        ctx.ensure(store.len());
        let cands = greedy_search_ext(
            &mut *ctx,
            &[medoid],
            self.params.build_window,
            self.params.build_window,
            None,
            |ids: &[u32], out: &mut Vec<f32>| store.score_block(&pq, ids, out),
            |x, out| {
                reader.neighbors_into(x, out);
                out.retain(|&nb| nb != id);
            },
        );
        // candidate pool: search results, minus self and tombstones
        // (deleted nodes must not gain new in-edges)
        let mut pool: Vec<u32> = cands
            .iter()
            .map(|c| c.id)
            .filter(|&x| x != id && !tomb.is_deleted(x))
            .collect();
        if pool.is_empty() {
            // every reachable candidate is tombstoned (a dense deleted
            // region with no consolidation yet): apply the
            // consolidation rule at insert time, deepened — walk
            // outward through the deleted region (bounded BFS) until
            // live nodes appear, so the new node is never orphaned
            let mut seen: HashSet<u32> = cands.iter().map(|c| c.id).collect();
            seen.insert(id);
            let mut frontier: Vec<u32> =
                cands.iter().map(|c| c.id).filter(|&x| x != id).collect();
            let cap = (self.params.build_window * self.params.max_degree).max(1024);
            let mut dnb: Vec<u32> = Vec::new();
            while pool.is_empty() && !frontier.is_empty() && seen.len() < cap {
                let mut next: Vec<u32> = Vec::new();
                for &d in &frontier {
                    reader.neighbors_into(d, &mut dnb);
                    for &x in dnb.iter() {
                        if !seen.insert(x) {
                            continue;
                        }
                        if tomb.is_deleted(x) {
                            next.push(x);
                        } else {
                            pool.push(x);
                        }
                    }
                }
                frontier = next;
            }
        }
        pool.sort_unstable();
        pool.dedup();
        let (alpha, r) = (self.params.alpha, self.params.max_degree);
        let selected = robust_prune(store, id, proj, &pool, alpha, r);
        self.graph.set_neighbors(id, &selected);
        if selected.is_empty() {
            // no live node reachable even through the deleted region:
            // re-anchor the entry point here ONLY when this node really
            // is the whole live set (the delete-everything case) —
            // otherwise keep the medoid where the live corpus lives
            if self.live_len() == 1 {
                self.medoid.store(id, Ordering::Release);
            }
            return;
        }
        // reverse edges
        let mut cur: Vec<u32> = Vec::with_capacity(r + 1);
        for &nb in &selected {
            reader.neighbors_into(nb, &mut cur);
            if cur.contains(&id) {
                continue;
            }
            cur.push(id);
            if cur.len() <= r {
                self.graph.set_neighbors(nb, &cur);
            } else {
                // overflow: re-prune nb's list including the new edge
                let nb_vec = store.decode(nb);
                let pruned = robust_prune(store, nb, &nb_vec, &cur, alpha, r);
                self.graph.set_neighbors(nb, &pruned);
            }
        }
    }

    /// Tombstone the vector with external id `ext_id`: O(1), honored by
    /// every search from this call on. Returns the internal slot.
    pub fn delete(&self, ext_id: u32) -> Result<u32, MutateError> {
        let _writer = self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut core = self.core_write();
        let id = match core.int_of.remove(&ext_id) {
            Some(id) => id,
            None => return Err(MutateError::UnknownId(ext_id)),
        };
        core.journal.deletes += 1;
        // set the bit while holding the core guard: once delete()
        // returns, no search can return this id
        self.tombs.set(id);
        Ok(id)
    }

    /// Rewire around tombstoned nodes, then compact every store, the
    /// graph, and the id map. The rewiring (the expensive half) runs
    /// under a read guard — searches continue; only the compaction swap
    /// holds the exclusive guard. No-op when nothing is deleted.
    pub fn consolidate(&self) -> ConsolidateReport {
        let t0 = std::time::Instant::now();
        let _writer = self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let removed = self.tombs.deleted();
        if removed == 0 {
            // nothing to compact — but still fold any pending insert
            // log into the base so insert-only workloads bound their
            // memory (the vectors already live in both stores; the log
            // is just the since-last-consolidation journal)
            let mut core = self.core_write();
            if !core.insert_log.is_empty() {
                core.insert_log.clear();
                core.journal.consolidations += 1;
            }
            return ConsolidateReport {
                removed: 0,
                rewired: 0,
                remaining: self.graph.len(),
                seconds: t0.elapsed().as_secs_f64(),
            };
        }
        let (alpha, r) = (self.params.alpha, self.params.max_degree);

        // --- phase 1 (concurrent with searches): rewire every live
        //     node that points at a deleted one. FreshDiskANN rule:
        //     pool = live neighbors ∪ live neighbors-of-deleted-
        //     neighbors, re-pruned with the same α slack.
        let mut rewired = 0usize;
        {
            let core = self.core_read();
            let store = core.primary.as_ref();
            let tomb = self.tombs.reader();
            let reader = self.graph.reader();
            let n = self.graph.len();
            let mut nb: Vec<u32> = Vec::new();
            let mut dnb: Vec<u32> = Vec::new();
            for id in 0..n as u32 {
                if tomb.is_deleted(id) {
                    continue;
                }
                reader.neighbors_into(id, &mut nb);
                if !nb.iter().any(|&x| tomb.is_deleted(x)) {
                    continue;
                }
                let mut pool: Vec<u32> =
                    nb.iter().copied().filter(|&x| !tomb.is_deleted(x)).collect();
                for &d in nb.iter() {
                    if !tomb.is_deleted(d) {
                        continue;
                    }
                    reader.neighbors_into(d, &mut dnb);
                    pool.extend(
                        dnb.iter()
                            .copied()
                            .filter(|&x| x != id && !tomb.is_deleted(x)),
                    );
                }
                pool.sort_unstable();
                pool.dedup();
                let p_vec = store.decode(id);
                let pruned = robust_prune(store, id, &p_vec, &pool, alpha, r);
                self.graph.set_neighbors(id, &pruned);
                rewired += 1;
            }
        }

        // --- phase 2 (exclusive): compact stores + graph + id map in
        //     one swap so no search observes them out of step
        let mut core = self.core_write();
        let n = self.graph.len();
        let tomb = self.tombs.reader();
        let keep: Vec<u32> = (0..n as u32).filter(|&i| !tomb.is_deleted(i)).collect();
        let mut remap = vec![u32::MAX; n];
        for (new_id, &old) in keep.iter().enumerate() {
            remap[old as usize] = new_id as u32;
        }
        let reader = self.graph.reader();
        let mut new_adj = Adjacency::new(keep.len(), r);
        let mut nb: Vec<u32> = Vec::new();
        let mut mapped: Vec<u32> = Vec::with_capacity(r);
        for (new_id, &old) in keep.iter().enumerate() {
            reader.neighbors_into(old, &mut nb);
            mapped.clear();
            mapped.extend(
                nb.iter()
                    .filter(|&&x| remap[x as usize] != u32::MAX)
                    .map(|&x| remap[x as usize]),
            );
            new_adj.set_neighbors(new_id as u32, &mapped);
        }
        core.primary.compact(&keep);
        core.secondary.compact(&keep);
        let new_ext: Vec<u32> = keep.iter().map(|&o| core.ext_of[o as usize]).collect();
        core.int_of = new_ext
            .iter()
            .enumerate()
            .map(|(i, &e)| (e, i as u32))
            .collect();
        core.ext_of = new_ext;
        core.insert_log.clear();
        core.journal.consolidations += 1;
        let old_medoid = self.medoid.load(Ordering::Acquire) as usize;
        let new_medoid = if old_medoid < n && remap[old_medoid] != u32::MAX {
            remap[old_medoid]
        } else {
            // the entry point itself was deleted: re-anchor at the
            // compacted store's medoid
            medoid_of(core.primary.as_ref())
        };
        self.graph.replace_frozen(&new_adj, keep.len());
        self.medoid.store(new_medoid, Ordering::Release);
        self.tombs.reset(keep.len());
        ConsolidateReport {
            removed,
            rewired,
            remaining: keep.len(),
            seconds: t0.elapsed().as_secs_f64(),
        }
    }

    /// Search with an externally projected query (the engine's
    /// batch-projected path; see
    /// [`LeanVecIndex::search_prepared`] for the contract).
    /// `query.vector()` must be the original full-dimensional vector.
    pub fn search_prepared(
        &self,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        let core = self.core_read();
        self.search_core(&core, ctx, q_proj, query)
    }

    /// The traversal + rerank body, under a held core read guard.
    fn search_core(
        &self,
        core: &Core,
        ctx: &mut SearchCtx,
        q_proj: &[f32],
        query: &Query,
    ) -> SearchResult {
        let k = query.top_k();
        let params = query.effective(SearchParams::default());
        let store = core.primary.as_ref();
        // snapshot the node count: anything inserted after this line is
        // invisible to this query (ids are filtered at neighbor fetch)
        let n = self.graph.len().min(store.len());
        if n == 0 || k == 0 {
            return SearchResult::default();
        }
        let pq = store.prepare(q_proj, self.sim);
        let tomb = self.tombs.reader();
        let reader = self.graph.reader();
        let deleted_hits = AtomicUsize::new(0);
        let user = query.filter_fn();
        // tombstones compose with the user's filter: both are routed
        // through, neither is returned; only tombstone skips land in
        // `deleted_skipped`. The user's predicate sees *external* ids —
        // the same namespace results are returned in — so allow-lists
        // stay valid across consolidations.
        let pred = |id: u32| {
            if tomb.is_deleted(id) {
                // ORDERING: Relaxed — per-query stat counter read back
                // on this same thread after the traversal returns.
                deleted_hits.fetch_add(1, Ordering::Relaxed);
                return false;
            }
            match user {
                Some(f) => f(core.ext_of[id as usize]),
                None => true,
            }
        };
        ctx.ensure(store.len());
        let capacity = params.rerank_window.max(k);
        let medoid = self.medoid.load(Ordering::Acquire).min(n as u32 - 1);
        let cands = greedy_search_ext(
            ctx,
            &[medoid],
            params.window,
            capacity,
            Some(&pred),
            |ids: &[u32], out: &mut Vec<f32>| store.score_block(&pq, ids, out),
            |id, out| {
                reader.neighbors_into(id, out);
                out.retain(|&x| (x as usize) < n);
            },
        );
        let take = params.rerank_window.max(k).min(cands.len());
        if !query.wants_rerank() {
            let take_k = k.min(cands.len());
            let ids: Vec<u32> = cands[..take_k]
                .iter()
                .map(|c| core.ext_of[c.id as usize])
                .collect();
            let scores: Vec<f32> = cands[..take_k].iter().map(|c| c.score).collect();
            // ORDERING: Relaxed — same-thread read of the counter above.
            let deleted_skipped = deleted_hits.load(Ordering::Relaxed);
            return SearchResult {
                ids,
                scores,
                stats: QueryStats {
                    primary_scored: ctx.stats.scored,
                    reranked: 0,
                    bytes_touched: ctx.stats.scored * store.bytes_per_vector(),
                    hops: ctx.stats.hops,
                    filtered: ctx.stats.filtered - deleted_skipped,
                    deleted_skipped,
                },
                ..SearchResult::default()
            };
        }
        let internal: Vec<u32> = cands[..take].iter().map(|c| c.id).collect();
        // ORDERING: Relaxed — same-thread read of the counter above.
        let deleted_skipped = deleted_hits.load(Ordering::Relaxed);
        let stats = QueryStats {
            primary_scored: ctx.stats.scored,
            reranked: take,
            bytes_touched: ctx.stats.scored * store.bytes_per_vector()
                + take * core.secondary.rerank_bytes_per_vector(),
            hops: ctx.stats.hops,
            filtered: ctx.stats.filtered - deleted_skipped,
            deleted_skipped,
        };
        // re-rank with secondary vectors in the original space (the one
        // shared ordering rule), then translate to external ids
        let scored = crate::index::leanvec_index::rerank_top_k(
            core.secondary.as_ref(),
            query.vector(),
            self.sim,
            &internal,
            k,
        );
        SearchResult {
            ids: scored
                .iter()
                .map(|&(_, id)| core.ext_of[id as usize])
                .collect(),
            scores: scored.iter().map(|&(s, _)| s).collect(),
            stats,
            ..SearchResult::default()
        }
    }
}

impl VectorIndex for LiveIndex {
    /// Full query path: project once (`A q`), traverse routing through
    /// tombstones, re-rank, return **external** ids.
    fn search(&self, ctx: &mut SearchCtx, query: &Query) -> SearchResult {
        let q_proj = self.model.project_query(query.vector());
        self.search_prepared(ctx, &q_proj, query)
    }

    /// Number of live (searchable) vectors.
    fn len(&self) -> usize {
        self.live_len()
    }

    fn dim(&self) -> usize {
        self.model.input_dim()
    }

    fn sim(&self) -> Similarity {
        self.sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProjectionKind;
    use crate::index::builder::IndexBuilder;
    use crate::util::rng::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    fn build(rows: &[Vec<f32>], d: usize, sim: Similarity) -> LeanVecIndex {
        let mut gp = GraphParams::for_similarity(sim);
        gp.max_degree = 16;
        gp.build_window = 40;
        IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(d)
            .graph_params(gp)
            .build(rows, None, sim)
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn pristine_live_index_matches_frozen_search_exactly() {
        let rs = rows(300, 16, 1);
        let frozen = build(&rs, 8, Similarity::L2);
        let live = LiveIndex::from_index(build(&rs, 8, Similarity::L2));
        let mut ctx = SearchCtx::new(rs.len());
        for seed in 0..10u64 {
            let q: Vec<f32> = rows(1, 16, 100 + seed).pop().unwrap();
            let query = Query::new(&q).k(10).window(30).rerank_window(60);
            let a = frozen.search(&mut ctx, &query);
            let b = live.search(&mut ctx, &query);
            assert_eq!(a.ids, b.ids);
            assert_eq!(a.scores, b.scores);
            assert_eq!(a.stats.primary_scored, b.stats.primary_scored);
            assert_eq!(a.stats.hops, b.stats.hops);
            assert_eq!(b.stats.deleted_skipped, 0);
        }
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn inserted_vectors_are_found() {
        let rs = rows(200, 12, 2);
        let live = LiveIndex::from_index(build(&rs, 6, Similarity::L2));
        // insert vectors far from the base cloud so they are their own
        // nearest neighbors
        let mut rng = Rng::new(77);
        for i in 0..20u32 {
            let v: Vec<f32> = (0..12)
                .map(|_| 10.0 + 0.05 * rng.gaussian_f32())
                .collect();
            live.insert(1000 + i, &v).unwrap();
        }
        assert_eq!(live.live_len(), 220);
        assert_eq!(live.journal().inserts, 20);
        assert_eq!(live.pending_inserts(), 20);
        let probe: Vec<f32> = vec![10.0; 12];
        let got = live.search_one(&Query::new(&probe).k(10).window(40));
        assert_eq!(got.ids.len(), 10);
        let hits = got.ids.iter().filter(|&&id| id >= 1000).count();
        assert!(hits >= 8, "inserted cluster not found: {:?}", got.ids);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn insert_validates() {
        let rs = rows(50, 8, 3);
        let live = LiveIndex::from_index(build(&rs, 4, Similarity::L2));
        assert_eq!(
            live.insert(3, &[0.0; 5]),
            Err(MutateError::DimMismatch {
                expected: 8,
                got: 5
            })
        );
        assert_eq!(live.insert(3, &[0.0; 8]), Err(MutateError::DuplicateId(3)));
        assert_eq!(live.insert(98, &[f32::NAN; 8]), Err(MutateError::NonFinite));
        assert_eq!(
            live.insert(98, &[f32::INFINITY; 8]),
            Err(MutateError::NonFinite)
        );
        assert!(live.insert(99, &[0.0; 8]).is_ok());
        assert_eq!(live.insert(99, &[0.0; 8]), Err(MutateError::DuplicateId(99)));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn deleted_ids_are_never_returned_but_routed_through() {
        let rs = rows(300, 12, 4);
        let live = LiveIndex::from_index(build(&rs, 6, Similarity::L2));
        let probe = rs[7].clone();
        let before = live.search_one(&Query::new(&probe).k(10).window(40));
        assert_eq!(before.ids[0], 7, "self query finds itself under L2");
        // delete the whole true top-5
        for &id in &before.ids[..5] {
            live.delete(id).unwrap();
        }
        assert_eq!(live.journal().deletes, 5);
        assert_eq!(live.live_len(), 295);
        assert_eq!(live.delete(before.ids[0]), Err(MutateError::UnknownId(before.ids[0])));
        let after = live.search_one(&Query::new(&probe).k(10).window(40));
        assert_eq!(after.ids.len(), 10, "still k results from live nodes");
        for id in &after.ids {
            assert!(!before.ids[..5].contains(id), "deleted id {id} returned");
        }
        assert!(
            after.stats.deleted_skipped >= 5,
            "traversal routed through the deleted region: {:?}",
            after.stats
        );
        assert_eq!(after.stats.filtered, 0, "no user filter attached");
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn user_filter_composes_with_tombstones() {
        let rs = rows(200, 12, 5);
        let live = LiveIndex::from_index(build(&rs, 6, Similarity::L2));
        // delete the two best answers so the traversal is guaranteed to
        // route through tombstones near the query
        let pre = live.search_one(&Query::new(&rs[4]).k(4).window(60));
        let doomed = [pre.ids[0], pre.ids[1]];
        for &id in &doomed {
            live.delete(id).unwrap();
        }
        let even = |id: u32| id % 2 == 0;
        let got = live.search_one(&Query::new(&rs[4]).k(10).window(60).filter(&even));
        assert!(got.ids.iter().all(|id| id % 2 == 0));
        for id in &doomed {
            assert!(!got.ids.contains(id), "deleted id {id} returned");
        }
        assert!(got.stats.filtered > 0, "odd ids counted as user-filtered");
        assert!(got.stats.deleted_skipped >= 1, "{:?}", got.stats);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn consolidate_compacts_and_keeps_external_ids() {
        let rs = rows(400, 12, 6);
        let live = LiveIndex::from_index(build(&rs, 6, Similarity::L2));
        // delete every third id, insert a small far-away cluster
        let mut deleted = Vec::new();
        for id in (0..400u32).step_by(3) {
            live.delete(id).unwrap();
            deleted.push(id);
        }
        let mut rng = Rng::new(9);
        for i in 0..30u32 {
            let v: Vec<f32> = (0..12).map(|_| 8.0 + 0.05 * rng.gaussian_f32()).collect();
            live.insert(5000 + i, &v).unwrap();
        }
        let live_before = live.live_len();
        let report = live.consolidate();
        assert_eq!(report.removed, deleted.len());
        assert!(report.rewired > 0);
        assert_eq!(report.remaining, live_before);
        assert_eq!(live.total_slots(), live_before, "slots compacted");
        assert_eq!(live.tombstone_fraction(), 0.0);
        assert_eq!(live.pending_inserts(), 0, "insert log folded in");
        assert_eq!(live.journal().consolidations, 1);
        // external ids survive compaction: a surviving base id still
        // finds itself, the inserted cluster still answers, deleted ids
        // stay gone
        let got = live.search_one(&Query::new(&rs[7]).k(5).window(40));
        assert_eq!(got.ids[0], 7);
        assert_eq!(got.stats.deleted_skipped, 0, "no tombstones left");
        let probe = vec![8.0f32; 12];
        let cluster = live.search_one(&Query::new(&probe).k(10).window(40));
        assert!(cluster.ids.iter().filter(|&&id| id >= 5000).count() >= 9);
        for q_id in [1u32, 7, 100] {
            let r = live.search_one(&Query::new(&rs[q_id as usize]).k(20).window(80));
            for id in &r.ids {
                assert!(!deleted.contains(id), "deleted {id} resurfaced");
            }
        }
        // a second consolidation is a no-op
        let again = live.consolidate();
        assert_eq!(again.removed, 0);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn delete_everything_then_reinsert() {
        let rs = rows(60, 8, 7);
        let live = LiveIndex::from_index(build(&rs, 4, Similarity::L2));
        for id in 0..60u32 {
            live.delete(id).unwrap();
        }
        assert_eq!(live.live_len(), 0);
        let empty = live.search_one(&Query::new(&rs[0]).k(5).window(20));
        assert!(empty.ids.is_empty(), "{:?}", empty.ids);
        live.consolidate();
        assert_eq!(live.total_slots(), 0);
        assert!(live.search_one(&Query::new(&rs[0]).k(5)).ids.is_empty());
        // the index recovers: re-insert a few vectors and search again
        for (i, r) in rs.iter().take(10).enumerate() {
            live.insert(i as u32, r).unwrap();
        }
        assert_eq!(live.live_len(), 10);
        let got = live.search_one(&Query::new(&rs[3]).k(3).window(20));
        assert_eq!(got.ids.first(), Some(&3));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn insert_after_deleting_everything_without_consolidation() {
        // the whole greedy candidate pool is tombstoned: the insert
        // must still end up reachable (medoid re-anchors to it)
        let rs = rows(60, 8, 10);
        let live = LiveIndex::from_index(build(&rs, 4, Similarity::L2));
        for id in 0..60u32 {
            live.delete(id).unwrap();
        }
        live.insert(100, &rs[0]).unwrap();
        assert_eq!(live.live_len(), 1);
        let got = live.search_one(&Query::new(&rs[0]).k(1).window(20));
        assert_eq!(got.ids, vec![100], "orphaned insert is unreachable");
        // and the next insert links to it through the new entry point
        live.insert(101, &rs[1]).unwrap();
        let got = live.search_one(&Query::new(&rs[1]).k(2).window(20));
        assert!(got.ids.contains(&101) && got.ids.contains(&100), "{:?}", got.ids);
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn insert_into_fully_deleted_cluster_links_through_tombstones() {
        // a dense far-away cluster is inserted then fully deleted; a new
        // vector landing there must link *through* the tombstoned
        // cluster to its live neighbors instead of being orphaned
        let rs = rows(200, 12, 11);
        let live = LiveIndex::from_index(build(&rs, 6, Similarity::L2));
        let mut rng = Rng::new(13);
        for i in 0..20u32 {
            let v: Vec<f32> = (0..12).map(|_| 9.0 + 0.05 * rng.gaussian_f32()).collect();
            live.insert(1000 + i, &v).unwrap();
        }
        for i in 0..20u32 {
            live.delete(1000 + i).unwrap();
        }
        let v: Vec<f32> = vec![9.0; 12];
        live.insert(2000, &v).unwrap();
        let got = live.search_one(&Query::new(&v).k(3).window(40));
        assert_eq!(got.ids.first(), Some(&2000), "{:?}", got.ids);
        assert!(got.ids.iter().all(|&id| id < 1000 || id == 2000));
    }

    #[test]

    #[cfg_attr(miri, ignore)] // mmap/threads/index-build: unsupported or too slow under Miri
    fn reinsert_after_delete_uses_fresh_slot() {
        let rs = rows(100, 8, 8);
        let live = LiveIndex::from_index(build(&rs, 4, Similarity::L2));
        live.delete(5).unwrap();
        let slot = live.insert(5, &rs[5]).unwrap();
        assert_eq!(slot, 100, "new internal slot appended");
        let got = live.search_one(&Query::new(&rs[5]).k(1).window(30));
        assert_eq!(got.ids, vec![5], "re-inserted id searchable again");
    }
}
