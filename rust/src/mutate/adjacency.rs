//! Concurrently-readable, single-writer adjacency for the live index.
//!
//! The frozen [`Adjacency`] is one flat slab — perfect for a built
//! graph, unusable for a growing one. [`LiveAdjacency`] shards the same
//! fixed-max-degree layout into blocks of [`SHARD_NODES`] nodes, each
//! behind its own `RwLock`, with the shard table itself published
//! through an `Arc` swap:
//!
//! * **readers** take an [`AdjacencyReader`] snapshot once per query
//!   (one brief table-lock to clone an `Arc`), then fetch neighbor
//!   lists under per-shard read locks — searches never contend with
//!   each other and only ever wait on a writer touching the *same*
//!   shard for the microseconds one `set_neighbors` takes;
//! * **the writer** (mutators are serialized upstream by
//!   [`crate::mutate::LiveIndex`]) edits one shard at a time, and grows
//!   the graph by appending shards: existing shard `Arc`s are reused in
//!   the new table, so in-flight readers keep seeing every edge update
//!   to the shards their snapshot covers.
//!
//! [`Adjacency`]: crate::graph::vamana::Adjacency

use crate::graph::vamana::Adjacency;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Nodes per shard. Large enough that the table stays short, small
/// enough that writer/reader collisions on one shard are rare.
pub const SHARD_NODES: usize = 1024;

/// One block of `SHARD_NODES` fixed-max-degree neighbor lists,
/// allocated at full capacity up front so edits never reallocate.
struct Shard {
    flat: Vec<u32>,
    len: Vec<u32>,
}

impl Shard {
    fn new(max_degree: usize) -> Shard {
        Shard {
            flat: vec![0; SHARD_NODES * max_degree],
            len: vec![0; SHARD_NODES],
        }
    }
}

type ShardTable = Arc<Vec<Arc<RwLock<Shard>>>>;

/// Growable sharded adjacency; see the module docs for the contract.
pub struct LiveAdjacency {
    max_degree: usize,
    table: RwLock<ShardTable>,
    nodes: AtomicUsize,
}

/// One query's snapshot of the shard table.
#[derive(Clone)]
pub struct AdjacencyReader {
    table: ShardTable,
    max_degree: usize,
}

// Lock poisoning throughout this module is recovered with
// `PoisonError::into_inner`: every critical section leaves the shard in
// a consistent state line-by-line (flat writes precede the len store
// that publishes them), so a peer's panic cannot expose a torn list —
// aborting every future search over a healthy graph would be worse.
impl AdjacencyReader {
    /// Copy `id`'s neighbor list into `out` (cleared first). Ids beyond
    /// the snapshot read as empty.
    pub fn neighbors_into(&self, id: u32, out: &mut Vec<u32>) {
        out.clear();
        let (s, i) = (id as usize / SHARD_NODES, id as usize % SHARD_NODES);
        if let Some(shard) = self.table.get(s) {
            let guard = shard.read().unwrap_or_else(std::sync::PoisonError::into_inner);
            let l = guard.len[i] as usize;
            let base = i * self.max_degree;
            out.extend_from_slice(&guard.flat[base..base + l]);
        }
    }

    /// `id`'s current out-degree.
    pub fn degree(&self, id: u32) -> usize {
        let (s, i) = (id as usize / SHARD_NODES, id as usize % SHARD_NODES);
        match self.table.get(s) {
            Some(shard) => shard.read().unwrap_or_else(std::sync::PoisonError::into_inner).len[i] as usize,
            None => 0,
        }
    }
}

impl LiveAdjacency {
    /// Thaw a frozen adjacency into the sharded live layout.
    pub fn from_adjacency(adj: &Adjacency) -> LiveAdjacency {
        let n = adj.len_nodes();
        let max_degree = adj.max_degree();
        let live = LiveAdjacency {
            max_degree,
            table: RwLock::new(Arc::new(Vec::new())),
            nodes: AtomicUsize::new(0),
        };
        live.replace_frozen(adj, n);
        live
    }

    /// Number of node slots (live + tombstoned).
    pub fn len(&self) -> usize {
        self.nodes.load(Ordering::Acquire)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deep structural check for the fsck layer, mirroring
    /// [`Adjacency::check_invariants`] over the sharded live layout:
    /// the shard table covers every published node, degrees respect the
    /// bound, neighbor ids stay inside the published node count, and no
    /// node lists itself. Degrees are validated before any slice is
    /// formed, and scanning stops after 16 violations.
    pub fn check_invariants(&self, out: &mut Vec<crate::util::invariants::Violation>) {
        use crate::util::invariants::Violation;
        let n = self.len();
        let table = Arc::clone(
            &self
                .table
                .read()
                .unwrap_or_else(std::sync::PoisonError::into_inner),
        );
        if table.len() * SHARD_NODES < n {
            out.push(Violation::new(
                "graph",
                "payload-size-mismatch",
                format!(
                    "{} shards cover {} slots but {n} nodes are published",
                    table.len(),
                    table.len() * SHARD_NODES
                ),
            ));
            return;
        }
        let start = out.len();
        'shards: for (s, shard) in table.iter().enumerate() {
            let guard = shard
                .read()
                .unwrap_or_else(std::sync::PoisonError::into_inner);
            for j in 0..SHARD_NODES {
                let i = s * SHARD_NODES + j;
                if i >= n {
                    break 'shards;
                }
                if out.len() - start >= 16 {
                    break 'shards;
                }
                let deg = guard.len[j] as usize;
                if deg > self.max_degree {
                    out.push(Violation::new(
                        "graph",
                        "degree-overflow",
                        format!("node {i}: degree {deg} > max {}", self.max_degree),
                    ));
                    continue;
                }
                let base = j * self.max_degree;
                let list = &guard.flat[base..base + deg];
                if let Some(&nb) = list.iter().find(|&&nb| nb as usize >= n) {
                    out.push(Violation::new(
                        "graph",
                        "neighbor-out-of-range",
                        format!("node {i}: neighbor {nb} >= {n} nodes"),
                    ));
                }
                if list.iter().any(|&nb| nb as usize == i) {
                    out.push(Violation::new(
                        "graph",
                        "self-loop",
                        format!("node {i} lists itself"),
                    ));
                }
            }
        }
    }

    pub fn max_degree(&self) -> usize {
        self.max_degree
    }

    /// Snapshot for one query (or one mutation's link phase).
    pub fn reader(&self) -> AdjacencyReader {
        AdjacencyReader {
            table: Arc::clone(&self.table.read().unwrap_or_else(std::sync::PoisonError::into_inner)),
            max_degree: self.max_degree,
        }
    }

    /// Install `id`'s neighbor list (truncated to the degree bound).
    pub fn set_neighbors(&self, id: u32, list: &[u32]) {
        debug_assert!((id as usize) < self.len());
        let (s, i) = (id as usize / SHARD_NODES, id as usize % SHARD_NODES);
        let table = Arc::clone(&self.table.read().unwrap_or_else(std::sync::PoisonError::into_inner));
        let mut shard = table[s].write().unwrap_or_else(std::sync::PoisonError::into_inner);
        let k = list.len().min(self.max_degree);
        let base = i * self.max_degree;
        shard.flat[base..base + k].copy_from_slice(&list[..k]);
        shard.len[i] = k as u32;
    }

    /// Append one node slot (empty neighbor list) and return its id.
    /// Grows the shard table when the last shard is full; existing
    /// shards are shared with in-flight readers.
    pub fn add_node(&self) -> u32 {
        let id = self.nodes.load(Ordering::Acquire);
        let needed_shards = (id + 1).div_ceil(SHARD_NODES);
        {
            let mut guard = self.table.write().unwrap_or_else(std::sync::PoisonError::into_inner);
            if guard.len() < needed_shards {
                let mut grown: Vec<Arc<RwLock<Shard>>> = guard.iter().map(Arc::clone).collect();
                while grown.len() < needed_shards {
                    grown.push(Arc::new(RwLock::new(Shard::new(self.max_degree))));
                }
                *guard = Arc::new(grown);
            }
        }
        // publish the slot only after its shard exists
        self.nodes.store(id + 1, Ordering::Release);
        id as u32
    }

    /// Freeze the first `n` nodes into a flat [`Adjacency`] (persist /
    /// consolidation). Writer-side only.
    pub fn to_adjacency(&self, n: usize) -> Adjacency {
        let reader = self.reader();
        let mut adj = Adjacency::new(n, self.max_degree);
        let mut buf = Vec::with_capacity(self.max_degree);
        for id in 0..n as u32 {
            reader.neighbors_into(id, &mut buf);
            adj.set_neighbors(id, &buf);
        }
        adj
    }

    /// Replace the whole graph with `adj` (consolidation swap). The
    /// caller must hold the live index's exclusive core guard so no
    /// search observes the new graph against old stores.
    pub fn replace_frozen(&self, adj: &Adjacency, n: usize) {
        assert_eq!(adj.max_degree(), self.max_degree);
        let shards = n.div_ceil(SHARD_NODES).max(1);
        let mut table: Vec<Arc<RwLock<Shard>>> = Vec::with_capacity(shards);
        for _ in 0..shards {
            table.push(Arc::new(RwLock::new(Shard::new(self.max_degree))));
        }
        for id in 0..n as u32 {
            let (s, i) = (id as usize / SHARD_NODES, id as usize % SHARD_NODES);
            let mut shard = table[s].write().unwrap_or_else(std::sync::PoisonError::into_inner);
            let list = adj.neighbors(id);
            let base = i * self.max_degree;
            shard.flat[base..base + list.len()].copy_from_slice(list);
            shard.len[i] = list.len() as u32;
        }
        // order: shrink the published count first so a racing reader
        // never addresses a node the new table does not cover
        self.nodes.store(0, Ordering::Release);
        *self.table.write().unwrap_or_else(std::sync::PoisonError::into_inner) = Arc::new(table);
        self.nodes.store(n, Ordering::Release);
    }

    /// Mean out-degree over the first `n` nodes (observability).
    pub fn avg_degree(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let reader = self.reader();
        let total: usize = (0..n as u32).map(|id| reader.degree(id)).sum();
        total as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frozen(n: usize, max_degree: usize) -> Adjacency {
        let mut adj = Adjacency::new(n, max_degree);
        for i in 0..n as u32 {
            let nb = [(i + 1) % n as u32, (i + 2) % n as u32];
            adj.set_neighbors(i, &nb);
        }
        adj
    }

    #[test]
    fn thaw_preserves_lists_across_shard_boundaries() {
        let n = SHARD_NODES + 37; // spans two shards
        let adj = frozen(n, 8);
        let live = LiveAdjacency::from_adjacency(&adj);
        assert_eq!(live.len(), n);
        let reader = live.reader();
        let mut buf = Vec::new();
        for id in [0u32, 1023, 1024, (n - 1) as u32] {
            reader.neighbors_into(id, &mut buf);
            assert_eq!(buf.as_slice(), adj.neighbors(id), "id {id}");
        }
    }

    #[test]
    fn add_node_grows_and_old_readers_stay_consistent() {
        let adj = frozen(10, 4);
        let live = LiveAdjacency::from_adjacency(&adj);
        let snap = live.reader();
        // grow past the first shard
        let mut last = 0;
        for _ in 0..SHARD_NODES {
            last = live.add_node();
        }
        assert_eq!(last as usize, 10 + SHARD_NODES - 1);
        assert_eq!(live.len(), 10 + SHARD_NODES);
        live.set_neighbors(last, &[0, 1]);
        let mut buf = Vec::new();
        // the fresh reader sees the new node; the old snapshot reads it
        // as empty (its shard did not exist then) but still sees edits
        // to nodes its shards cover
        live.reader().neighbors_into(last, &mut buf);
        assert_eq!(buf, vec![0, 1]);
        snap.neighbors_into(last, &mut buf);
        assert!(buf.is_empty());
        live.set_neighbors(3, &[7]);
        snap.neighbors_into(3, &mut buf);
        assert_eq!(buf, vec![7], "shared shard shows writer edits");
    }

    #[test]
    fn roundtrip_to_adjacency() {
        let adj = frozen(300, 6);
        let live = LiveAdjacency::from_adjacency(&adj);
        live.set_neighbors(5, &[1, 2, 3]);
        let back = live.to_adjacency(300);
        assert_eq!(back.neighbors(5), &[1, 2, 3]);
        for id in [0u32, 100, 299] {
            assert_eq!(back.neighbors(id), adj.neighbors(id));
        }
    }

    #[test]
    fn replace_frozen_swaps_whole_graph() {
        let live = LiveAdjacency::from_adjacency(&frozen(100, 6));
        let smaller = frozen(40, 6);
        live.replace_frozen(&smaller, 40);
        assert_eq!(live.len(), 40);
        let mut buf = Vec::new();
        live.reader().neighbors_into(39, &mut buf);
        assert_eq!(buf.as_slice(), smaller.neighbors(39));
        assert!(live.avg_degree(40) > 1.9);
    }

    #[test]
    fn degree_bound_enforced() {
        let live = LiveAdjacency::from_adjacency(&frozen(10, 3));
        live.set_neighbors(0, &[1, 2, 3, 4, 5]);
        let mut buf = Vec::new();
        live.reader().neighbors_into(0, &mut buf);
        assert_eq!(buf.len(), 3, "list truncated to max_degree");
    }
}
