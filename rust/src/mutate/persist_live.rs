//! Live snapshot persistence: the five frozen core sections plus three
//! live sections, stamped [`FORMAT_VERSION_LIVE`].
//!
//! A live snapshot *reshapes the meaning* of the core sections — store
//! rows may be tombstoned, result ids go through the external-id map —
//! so per the PR 2 versioning contract the format version is bumped
//! rather than relying on ignorable extra sections: a frozen-only
//! reader ([`LeanVecIndex::load`]) meeting a live snapshot fails with
//! [`SnapshotError::UnsupportedVersion`] instead of silently serving
//! deleted vectors. A pristine live index (no mutations ever) writes a
//! plain version-1 snapshot, byte-identical to
//! [`LeanVecIndex::save`].
//!
//! New sections (byte layout in `docs/SNAPSHOT_FORMAT.md`):
//!
//! * `TOMBS` — slot count + the tombstone bitmap, 64 ids per word;
//! * `IDMAP` — internal slot -> external id, one `u32` per slot;
//! * `MUTLOG` — lifetime mutation counters + the pending insert log
//!   (external id + full-D vector per insert since the last
//!   consolidation — what a model re-train against drifted data would
//!   consume).
//!
//! Saving is byte-deterministic, and save → load → save reproduces the
//! file exactly (the round-trip tests in `rust/tests/mutate.rs` assert
//! it), so mutated indexes keep the frozen snapshot guarantee: a loaded
//! copy serves bit-identical results.
//!
//! [`LeanVecIndex::load`]: crate::index::LeanVecIndex::load
//! [`LeanVecIndex::save`]: crate::index::LeanVecIndex::save

use crate::data::io::bin;
use crate::graph::vamana::VamanaGraph;
use crate::index::persist::{
    core_sections, load_core_sections, read_sections_any, tag_str, write_sections_versioned,
    MetaFacts, RawSection, SnapshotError, SnapshotMeta, FORMAT_VERSION, FORMAT_VERSION_LIVE,
    SECTION_IDMAP, SECTION_MUTLOG, SECTION_TOMBS,
};
use crate::mutate::live::{LiveIndex, MutationJournal};
use crate::mutate::tombstones::Tombstones;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::Ordering;

fn corrupt(what: impl Into<String>) -> SnapshotError {
    SnapshotError::Corrupt(what.into())
}

impl LiveIndex {
    /// Write the live index to `path`. Searches continue while the
    /// snapshot is taken (a read guard is held); mutators wait.
    /// Pristine indexes produce a plain frozen (version-1) snapshot;
    /// any mutation history produces a [`FORMAT_VERSION_LIVE`] file
    /// with the `TOMBS`/`IDMAP`/`MUTLOG` sections appended.
    pub fn save(&self, path: &Path, meta: &SnapshotMeta) -> Result<u64, SnapshotError> {
        // recover a poisoned writer lock: the snapshot only needs the
        // core read guard below for consistency (see live.rs)
        let _writer = self.writer.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let core = self.core_read();
        let n = core.primary.len();
        let graph = VamanaGraph {
            adj: self.graph.to_adjacency(n),
            medoid: self.medoid.load(Ordering::Acquire),
            params: self.params,
            sim: self.sim,
            build_seconds: self.graph_build_seconds,
        };
        let facts = MetaFacts {
            sim: self.sim,
            projection: self.model.kind,
            primary: self.primary_compression,
            secondary: self.secondary_compression,
            n,
            input_dim: self.model.input_dim(),
            target_dim: self.model.target_dim(),
            breakdown: self.build_breakdown,
        };
        let mut sections = core_sections(
            meta,
            &facts,
            &self.model,
            core.primary.as_ref(),
            core.secondary.as_ref(),
            &graph,
        );
        let identity_ids = core.ext_of.iter().enumerate().all(|(i, &e)| e == i as u32);
        if self.tombs.deleted() == 0
            && core.journal == MutationJournal::default()
            && core.insert_log.is_empty()
            && identity_ids
        {
            return write_sections_versioned(path, &sections, FORMAT_VERSION);
        }

        // TOMBS: slot count, canonical word count, bitmap words
        let mut tombs = Vec::new();
        bin::put_u64(&mut tombs, n as u64);
        let canonical = n.div_ceil(64);
        bin::put_u64(&mut tombs, canonical as u64);
        let words = self.tombs.to_words();
        for i in 0..canonical {
            let w = words.get(i).copied().unwrap_or(0);
            tombs.extend_from_slice(&w.to_le_bytes());
        }

        // IDMAP: internal slot -> external id
        let mut idmap = Vec::new();
        bin::put_u32s(&mut idmap, &core.ext_of);

        // MUTLOG: lifetime counters + pending insert log
        let mut log = Vec::new();
        bin::put_u64(&mut log, core.journal.inserts);
        bin::put_u64(&mut log, core.journal.deletes);
        bin::put_u64(&mut log, core.journal.consolidations);
        bin::put_u64(&mut log, core.insert_log.len() as u64);
        for (ext, vec) in &core.insert_log {
            bin::put_u32(&mut log, *ext);
            bin::put_f32s(&mut log, vec);
        }

        sections.push(RawSection::new(SECTION_TOMBS, tombs));
        sections.push(RawSection::new(SECTION_IDMAP, idmap));
        sections.push(RawSection::new(SECTION_MUTLOG, log));
        write_sections_versioned(path, &sections, FORMAT_VERSION_LIVE)
    }

    /// Load a live *or* frozen snapshot into a [`LiveIndex`]. The
    /// loaded copy serves bit-identical results to the saved one —
    /// same ids, scores, and [`QueryStats`] — and re-saving it
    /// reproduces the file byte-for-byte.
    ///
    /// [`QueryStats`]: crate::index::query::QueryStats
    pub fn load(path: &Path) -> Result<(LiveIndex, SnapshotMeta), SnapshotError> {
        let (version, sections) = read_sections_any(path)?;
        let (index, meta) = load_core_sections(&sections)?;
        let mut live = LiveIndex::from_index(index);
        if version < FORMAT_VERSION_LIVE {
            return Ok((live, meta));
        }
        let find = |tag: [u8; 8]| -> Result<&[u8], SnapshotError> {
            sections
                .iter()
                .find(|s| s.tag == tag)
                .map(|s| s.bytes.as_slice())
                .ok_or_else(|| SnapshotError::MissingSection(tag_str(&tag)))
        };
        let n = live.total_slots();

        // TOMBS
        let mut cur = bin::Cursor::new(find(SECTION_TOMBS)?);
        let slots = cur.get_u64()? as usize;
        if slots != n {
            return Err(corrupt(format!(
                "tombstone bitmap covers {slots} slots, stores hold {n}"
            )));
        }
        let canonical = n.div_ceil(64);
        let word_count = cur.get_u64()? as usize;
        if word_count != canonical {
            return Err(corrupt("tombstone bitmap word count disagrees with slots"));
        }
        let mut words = Vec::with_capacity(word_count);
        for _ in 0..word_count {
            words.push(cur.get_u64()?);
        }
        if cur.remaining() != 0 {
            return Err(corrupt("trailing bytes in tombstone section"));
        }
        let tail_bits = n % 64;
        if tail_bits != 0 {
            if let Some(&last) = words.last() {
                if last >> tail_bits != 0 {
                    return Err(corrupt("tombstone bit set beyond the last slot"));
                }
            }
        }

        // IDMAP
        let mut cur = bin::Cursor::new(find(SECTION_IDMAP)?);
        let ext_of = cur.get_u32s()?;
        if ext_of.len() != n || cur.remaining() != 0 {
            return Err(corrupt("id map length disagrees with stores"));
        }

        // MUTLOG
        let mut cur = bin::Cursor::new(find(SECTION_MUTLOG)?);
        let journal = MutationJournal {
            inserts: cur.get_u64()?,
            deletes: cur.get_u64()?,
            consolidations: cur.get_u64()?,
        };
        let pending = cur.get_u64()? as usize;
        if pending > n {
            return Err(corrupt("insert log longer than the store"));
        }
        let dim = live.model.input_dim();
        let mut insert_log = Vec::with_capacity(pending);
        for _ in 0..pending {
            let ext = cur.get_u32()?;
            let vec = cur.get_f32s()?;
            if vec.len() != dim {
                return Err(corrupt("insert-log vector has the wrong dimensionality"));
            }
            insert_log.push((ext, vec));
        }
        if cur.remaining() != 0 {
            return Err(corrupt("trailing bytes in mutation log"));
        }

        // install the live state: tombstones first, then the id maps —
        // a live external id appearing twice is corruption
        live.tombs = Tombstones::from_words(&words, n);
        let tomb = live.tombs.reader();
        let mut int_of: HashMap<u32, u32> = HashMap::with_capacity(n);
        for (id, &ext) in ext_of.iter().enumerate() {
            if tomb.is_deleted(id as u32) {
                continue;
            }
            if int_of.insert(ext, id as u32).is_some() {
                return Err(corrupt(format!("external id {ext} is live twice")));
            }
        }
        {
            let mut core = live.core_write();
            core.ext_of = ext_of;
            core.int_of = int_of;
            core.insert_log = insert_log;
            core.journal = journal;
        }
        Ok((live, meta))
    }
}
