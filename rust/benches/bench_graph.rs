//! Graph benches: per-search cost vs window and vs representation
//! (the end-to-end mechanism behind figs 4/5 at micro scale).

use leanvec::config::{Compression, GraphParams, ProjectionKind};
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::query::{Query, VectorIndex};
use leanvec::util::rng::Rng;
use leanvec::util::stats::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let ds = generate(&SynthSpec::ood("bench-graph", 256, 6_000, 128));
    println!(
        "== bench_graph: {} x {} OOD dataset ==",
        ds.database.len(),
        ds.dim
    );

    let mut gp = GraphParams::for_similarity(ds.similarity);
    gp.max_degree = 32;
    gp.build_window = 64;

    for (name, proj, d, comp) in [
        ("fp16-fullD", ProjectionKind::None, 0usize, Compression::F16),
        ("lvq8-fullD", ProjectionKind::None, 0, Compression::Lvq8),
        ("leanvec-d64", ProjectionKind::OodEigSearch, 64, Compression::Lvq8),
    ] {
        let index = IndexBuilder::new()
            .projection(proj)
            .target_dim(d)
            .primary(comp)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
        let mut ctx = SearchCtx::new(index.len());
        let mut rng = Rng::new(5);
        for window in [20usize, 50, 100] {
            let r = bench(&format!("search/{name}/w{window}"), budget, || {
                let q = &ds.test_queries[rng.below(ds.test_queries.len())];
                std::hint::black_box(
                    index.search(&mut ctx, &Query::new(q).k(10).window(window)),
                );
            });
            println!("{r}");
        }
        println!();
    }
}
