//! Micro-benchmarks for the scoring hot path (custom harness; criterion
//! is unavailable offline). This is the Fig.-1 mechanism at micro scale:
//! score time tracks bytes/vector, so LVQ8 < FP16 < F32 per-score cost
//! on a memory-bound loop.

use leanvec::config::Similarity;
use leanvec::index::leanvec_index::make_store;
use leanvec::util::rng::Rng;
use leanvec::util::stats::bench;
use std::time::Duration;

fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

fn main() {
    let budget = Duration::from_millis(300);
    println!("== bench_distances: fused scoring, one vector per call ==");
    for d in [160usize, 512, 768] {
        let data = rows(4096, d, 42);
        let q: Vec<f32> = rows(1, d, 7).pop().unwrap();
        let mut rng = Rng::new(9);
        let ids: Vec<u32> = (0..4096).map(|_| rng.below(4096) as u32).collect();

        for comp in ["f32", "f16", "lvq8", "lvq4", "lvq4x8"] {
            let store = make_store(&data, leanvec::config::Compression::parse(comp).unwrap());
            let pq = store.prepare(&q, Similarity::InnerProduct);
            let mut i = 0usize;
            let r = bench(&format!("score/{comp}/d{d}"), budget, || {
                let id = ids[i & 4095];
                i = i.wrapping_add(1);
                std::hint::black_box(store.score(&pq, id));
            });
            println!(
                "{r}  [{} B/vec -> {:.2} GB/s effective]",
                store.bytes_per_vector(),
                store.bytes_per_vector() as f64 / r.mean_ns
            );
        }
        println!();
    }

    println!("== prepare (once per query) ==");
    for d in [160usize, 768] {
        let data = rows(256, d, 3);
        let store = make_store(&data, leanvec::config::Compression::Lvq8);
        let q: Vec<f32> = rows(1, d, 8).pop().unwrap();
        let r = bench(&format!("prepare/lvq8/d{d}"), budget, || {
            std::hint::black_box(store.prepare(&q, Similarity::InnerProduct));
        });
        println!("{r}");
    }
}
