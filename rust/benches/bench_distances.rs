//! Micro-benchmarks for the scoring hot path (custom harness; criterion
//! is unavailable offline). This is the Fig.-1 mechanism at micro scale:
//! score time tracks bytes/vector, so LVQ8 < FP16 < F32 per-score cost
//! on a memory-bound loop.
//!
//! Two sections:
//! * per-kernel: raw ns/vector for every kernel in the `simd` layer,
//!   scalar reference vs dispatched (the headline: >= 2x on an AVX2
//!   host for the LVQ4/LVQ8/F16 kernels at dim 128/768)
//! * per-store: the fused `score()`/`score_block()` paths end to end

use leanvec::config::Similarity;
use leanvec::index::leanvec_index::make_store;
use leanvec::simd;
use leanvec::util::rng::Rng;
use leanvec::util::stats::bench;
use std::time::Duration;

fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

/// Print one scalar-vs-dispatched kernel pair as ns/vector + speedup.
fn report_pair(kernel: &str, d: usize, scalar_ns: f64, dispatched_ns: f64) {
    println!(
        "kernel/{kernel:<6} d{d:<4} scalar {scalar_ns:>8.1} ns/vec   dispatched {dispatched_ns:>8.1} ns/vec   {:.2}x",
        scalar_ns / dispatched_ns.max(1e-9)
    );
}

/// Per-kernel microbench: every store kind's kernel at dim 128 and 768,
/// scalar reference vs the dispatched implementation, over a working
/// set large enough to stream from cache like real traversal batches.
fn bench_kernels(budget: Duration) {
    const N: usize = 4096;
    println!("== per-kernel: ns/vector, scalar vs dispatched ==");
    for d in [128usize, 768] {
        let mut rng = Rng::new(42);
        let f32_rows: Vec<f32> = (0..N * d).map(|_| rng.gaussian_f32()).collect();
        let f16_rows: Vec<u16> = leanvec::util::f16::encode_slice(&f32_rows);
        let u8_rows: Vec<u8> = (0..N * d).map(|_| rng.below(256) as u8).collect();
        let s4 = d.div_ceil(2);
        let u4_rows: Vec<u8> = (0..N * s4).map(|_| rng.below(256) as u8).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let ids: Vec<usize> = (0..N).map(|_| rng.below(N)).collect();

        // f32 dot
        let mut i = 0usize;
        let rs = bench(&format!("scalar/f32/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::scalar::dot_f32(&f32_rows[r..r + d], &q));
        });
        let mut i = 0usize;
        let rd = bench(&format!("dispatch/f32/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::dot_f32(&f32_rows[r..r + d], &q));
        });
        report_pair("f32", d, rs.mean_ns, rd.mean_ns);

        // fused f16 decode+dot
        let mut i = 0usize;
        let rs = bench(&format!("scalar/f16/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::scalar::dot_f16(&f16_rows[r..r + d], &q));
        });
        let mut i = 0usize;
        let rd = bench(&format!("dispatch/f16/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::dot_f16(&f16_rows[r..r + d], &q));
        });
        report_pair("f16", d, rs.mean_ns, rd.mean_ns);

        // LVQ8 u8 widen+FMA dot
        let mut i = 0usize;
        let rs = bench(&format!("scalar/lvq8/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::scalar::dot_u8(&u8_rows[r..r + d], &q));
        });
        let mut i = 0usize;
        let rd = bench(&format!("dispatch/lvq8/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * d;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::dot_u8(&u8_rows[r..r + d], &q));
        });
        report_pair("lvq8", d, rs.mean_ns, rd.mean_ns);

        // LVQ4 nibble-unpack dot
        let mut i = 0usize;
        let rs = bench(&format!("scalar/lvq4/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * s4;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::scalar::dot_u4(&u4_rows[r..r + s4], &q));
        });
        let mut i = 0usize;
        let rd = bench(&format!("dispatch/lvq4/d{d}"), budget, || {
            let r = ids[i & (N - 1)] * s4;
            i = i.wrapping_add(1);
            std::hint::black_box(simd::dot_u4(&u4_rows[r..r + s4], &q));
        });
        report_pair("lvq4", d, rs.mean_ns, rd.mean_ns);

        // LVQ4x8 residual combine (both levels of one row)
        let mut i = 0usize;
        let rs = bench(&format!("scalar/lvq4x8/d{d}"), budget, || {
            let id = ids[i & (N - 1)];
            i = i.wrapping_add(1);
            std::hint::black_box(simd::scalar::dot_u4_u8(
                &u4_rows[id * s4..id * s4 + s4],
                &u8_rows[id * d..id * d + d],
                &q,
            ));
        });
        let mut i = 0usize;
        let rd = bench(&format!("dispatch/lvq4x8/d{d}"), budget, || {
            let id = ids[i & (N - 1)];
            i = i.wrapping_add(1);
            std::hint::black_box(simd::dot_u4_u8(
                &u4_rows[id * s4..id * s4 + s4],
                &u8_rows[id * d..id * d + d],
                &q,
            ));
        });
        report_pair("lvq4x8", d, rs.mean_ns, rd.mean_ns);
        println!();
    }
}

fn main() {
    // first line of output: which instruction set the dispatcher picked
    // (CI greps the log for this so a silently-scalar runner is visible)
    println!("kernel dispatch: {}", simd::active_features());
    let budget = Duration::from_millis(300);

    bench_kernels(budget);

    println!("== bench_distances: fused scoring, one vector per call ==");
    for d in [160usize, 512, 768] {
        let data = rows(4096, d, 42);
        let q: Vec<f32> = rows(1, d, 7).pop().unwrap();
        let mut rng = Rng::new(9);
        let ids: Vec<u32> = (0..4096).map(|_| rng.below(4096) as u32).collect();

        for comp in ["f32", "f16", "lvq8", "lvq4", "lvq4x8"] {
            let store = make_store(&data, leanvec::config::Compression::parse(comp).unwrap());
            let pq = store.prepare(&q, Similarity::InnerProduct);
            let mut i = 0usize;
            let r = bench(&format!("score/{comp}/d{d}"), budget, || {
                let id = ids[i & 4095];
                i = i.wrapping_add(1);
                std::hint::black_box(store.score(&pq, id));
            });
            println!(
                "{r}  [{} B/vec -> {:.2} GB/s effective]",
                store.bytes_per_vector(),
                store.bytes_per_vector() as f64 / r.mean_ns
            );
            // the blocked path the request loop actually uses
            let mut out: Vec<f32> = Vec::with_capacity(64);
            let mut start = 0usize;
            let rb = bench(&format!("score_block/{comp}/d{d}"), budget, || {
                let s = start & 4095;
                let end = (s + 64).min(4096);
                store.score_block(&pq, &ids[s..end], &mut out);
                start = start.wrapping_add(64);
                std::hint::black_box(out.last().copied());
            });
            println!("{}  [{:.1} ns/vec in 64-wide blocks]", rb, rb.mean_ns / 64.0);
        }
        println!();
    }

    println!("== prepare (once per query) ==");
    for d in [160usize, 768] {
        let data = rows(256, d, 3);
        let store = make_store(&data, leanvec::config::Compression::Lvq8);
        let q: Vec<f32> = rows(1, d, 8).pop().unwrap();
        let r = bench(&format!("prepare/lvq8/d{d}"), budget, || {
            std::hint::black_box(store.prepare(&q, Similarity::InnerProduct));
        });
        println!("{r}");
    }
}
