//! Projection-learning benches: PCA vs eigsearch vs Frank-Wolfe at the
//! paper's (D, d) shapes (Fig. 2 / Fig. 13 runtimes at bench scale).

use leanvec::leanvec::eigsearch::{eigsearch, NativeTopd, TopdBackend};
use leanvec::leanvec::fw::{frank_wolfe, FwParams, NativeStepper};
use leanvec::leanvec::pca::pca;
use leanvec::linalg::Matrix;
use leanvec::util::rng::Rng;
use leanvec::util::stats::bench;
use std::time::Duration;

fn psd(dd: usize, n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut x = Matrix::randn(n, dd, &mut rng);
    for row in x.data.chunks_mut(dd) {
        for (c, v) in row.iter_mut().enumerate() {
            *v *= 1.0 / (1.0 + c as f32 * 0.1);
        }
    }
    x.second_moment()
}

fn main() {
    let budget = Duration::from_millis(300);
    println!("== bench_training ==");
    for (dd, d) in [(200usize, 128usize), (256, 96), (512, 128)] {
        let kx = psd(dd, 800, 1);
        let kq = psd(dd, 400, 2);

        let r = bench(&format!("pca/D{dd}_d{d}"), budget, || {
            std::hint::black_box(pca(&kx, d));
        });
        println!("{r}");

        let r = bench(&format!("topd-subspace/D{dd}_d{d}"), budget, || {
            std::hint::black_box(NativeTopd.topd(&kx, d));
        });
        println!("{r}");

        let r = bench(&format!("eigsearch/D{dd}_d{d}"), budget, || {
            std::hint::black_box(eigsearch(&kq, &kx, d, &mut NativeTopd));
        });
        println!("{r}");

        let mut rng = Rng::new(3);
        let p0 = leanvec::linalg::qr::random_orthonormal(d, dd, &mut rng);
        let r = bench(&format!("fw-10iters/D{dd}_d{d}"), budget, || {
            std::hint::black_box(frank_wolfe(
                &mut NativeStepper,
                p0.clone(),
                p0.clone(),
                &kq,
                &kx,
                FwParams {
                    max_iters: 10,
                    tol: 0.0,
                    ..FwParams::default()
                },
            ));
        });
        println!("{r}");
        println!();
    }
}
