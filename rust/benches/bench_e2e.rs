//! End-to-end bench: the paper's headline comparison (Fig. 1 / Fig. 5)
//! at bench scale — QPS at matched recall across representations, the
//! serving engine's throughput, and the parallel-build speedup curve
//! (emitted machine-readable to `BENCH_build.json` so future changes
//! can track the trajectory; the paper's headline is a 4.9x faster
//! build).

use leanvec::config::{Compression, GraphParams, ProjectionKind};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig, Metrics};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::experiments::harness::{qps_at_recall, qps_recall_curve};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::{LeanVecIndex, SearchParams};
use leanvec::index::persist::SnapshotMeta;
use leanvec::index::query::{Query, VectorIndex};
use leanvec::mutate::LiveIndex;
use leanvec::shard::{ShardSpec, ShardedIndex};
use leanvec::util::json::Json;
use leanvec::util::rng::Rng;
use std::sync::Arc;

/// Build-time breakdown at 1, 2 and all-cores threads; writes
/// BENCH_build.json with the speedup curve and recall parity.
fn bench_build_trajectory(
    ds: &leanvec::data::synth::Dataset,
    gp: GraphParams,
    truth: &[Vec<u32>],
    k: usize,
) {
    let all_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut sweep: Vec<usize> = vec![1, 2, all_cores];
    sweep.sort_unstable();
    sweep.dedup();

    println!("\n== parallel build trajectory ({} cores available) ==", all_cores);
    let mut rows = Vec::new();
    let mut last_index: Option<LeanVecIndex> = None;
    let mut serial_total = 0.0f64;
    // projection training is serial at every thread count, so the
    // headline speedup is reported over the phases build_threads
    // actually parallelizes (project + quantize + graph), alongside the
    // Amdahl-capped total ratio.
    let mut serial_parallel_phases = 0.0f64;
    for &threads in &sweep {
        let t0 = std::time::Instant::now();
        let index = IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(160)
            .primary(Compression::Lvq8)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build_threads(threads)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
        let wall = t0.elapsed().as_secs_f64();
        let b = index.build_breakdown;
        let parallel_phases = b.project_seconds + b.quantize_seconds + b.graph_seconds;
        if threads == 1 {
            serial_total = b.total();
            serial_parallel_phases = parallel_phases;
        }
        let reqs: Vec<Query> = ds.test_queries.iter().map(|q| Query::new(q).k(k)).collect();
        let got: Vec<Vec<u32>> = index
            .search_batch(&reqs, threads)
            .into_iter()
            .map(|r| r.ids)
            .collect();
        let recall = recall_at_k(&got, truth, k);
        let speedup_total = if b.total() > 0.0 { serial_total / b.total() } else { 0.0 };
        let speedup_build = if parallel_phases > 0.0 {
            serial_parallel_phases / parallel_phases
        } else {
            0.0
        };
        println!(
            "threads {threads:>2}: total {:.2}s (train {:.2} | project {:.2} | quantize {:.2} | graph {:.2}) \
             build-speedup {speedup_build:.2}x total-speedup {speedup_total:.2}x recall@{k} {recall:.3}",
            b.total(),
            b.train_seconds,
            b.project_seconds,
            b.quantize_seconds,
            b.graph_seconds
        );
        rows.push(Json::obj(vec![
            ("threads", Json::num(threads as f64)),
            ("wall_seconds", Json::num(wall)),
            ("train_seconds", Json::num(b.train_seconds)),
            ("project_seconds", Json::num(b.project_seconds)),
            ("quantize_seconds", Json::num(b.quantize_seconds)),
            ("graph_seconds", Json::num(b.graph_seconds)),
            ("total_seconds", Json::num(b.total())),
            ("parallel_phase_seconds", Json::num(parallel_phases)),
            ("speedup_parallel_phases_vs_serial", Json::num(speedup_build)),
            ("speedup_total_vs_serial", Json::num(speedup_total)),
            ("k", Json::num(k as f64)),
            ("recall_at_k", Json::num(recall)),
        ]));
        last_index = Some(index);
    }

    // snapshot write/load timing rides along with the build trajectory:
    // with the build/serve split, load time is what a serving process
    // actually pays at startup
    let snap_path =
        std::env::temp_dir().join(format!("leanvec-bench-{}.leanvec", std::process::id()));
    let (mut snap_bytes, mut snap_write_s, mut snap_load_s) = (0u64, 0.0f64, 0.0f64);
    if let Some(index) = last_index {
        let t0 = std::time::Instant::now();
        snap_bytes = index
            .save(&snap_path, &SnapshotMeta::default())
            .expect("snapshot save");
        snap_write_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (loaded, _) = LeanVecIndex::load(&snap_path).expect("snapshot load");
        snap_load_s = t0.elapsed().as_secs_f64();
        assert_eq!(loaded.len(), index.len(), "snapshot round-trip size");
        std::fs::remove_file(&snap_path).ok();
        println!(
            "snapshot: {:.1} MiB, write {snap_write_s:.3}s, load {snap_load_s:.3}s",
            snap_bytes as f64 / (1024.0 * 1024.0)
        );
    }

    let out = Json::obj(vec![
        ("dataset", Json::str(&ds.name)),
        ("n", Json::num(ds.database.len() as f64)),
        ("dim", Json::num(ds.dim as f64)),
        ("target_dim", Json::num(160.0)),
        ("available_parallelism", Json::num(all_cores as f64)),
        ("snapshot_bytes", Json::num(snap_bytes as f64)),
        ("snapshot_write_seconds", Json::num(snap_write_s)),
        ("snapshot_load_seconds", Json::num(snap_load_s)),
        ("builds", Json::Arr(rows)),
    ]);
    match std::fs::write("BENCH_build.json", out.to_pretty()) {
        Ok(()) => println!("[saved BENCH_build.json]"),
        Err(e) => eprintln!("could not write BENCH_build.json: {e}"),
    }
}

/// Search-side baseline at one fixed operating point — emitted
/// machine-readable to `BENCH_search.json` so the perf trajectory of
/// the scoring kernels has an end-to-end anchor: QPS (single-thread
/// sequential and all-core batch) + recall@10 at a fixed window, plus
/// which kernel set the dispatcher picked and a flat-scan point for
/// the linear-scan path.
/// Sharded scatter-gather arm: shards=1 vs shards=4 over the same
/// corpus, same model, measured closed-loop from one submitter thread
/// (the scatter fans each query across per-shard threads — the latency
/// path sharding exists for). Each shard holds n/4 vectors, so its
/// beam converges with a smaller per-shard window at equal union
/// recall; the sweep picks the smallest window that holds recall@k,
/// and the headline is sharded QPS over unsharded QPS at that matched
/// operating point. Returns the JSON fragment embedded under
/// `"sharded"` in BENCH_search.json.
fn bench_sharded(
    ds: &leanvec::data::synth::Dataset,
    gp: GraphParams,
    truth: &[Vec<u32>],
    k: usize,
) -> Json {
    const WINDOW: usize = 60;
    const SHARDS: usize = 4;
    println!("\n== sharded scatter-gather ({SHARDS} shards vs 1, window {WINDOW}) ==");
    let configure = move |b: IndexBuilder| {
        b.projection(ProjectionKind::OodEigSearch)
            .target_dim(160)
            .primary(Compression::Lvq8)
            .secondary(Compression::F16)
            .graph_params(gp)
    };
    let one = ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(1),
        0,
        configure,
    );
    let four = ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(SHARDS),
        0,
        configure,
    );
    // closed-loop from one submitter, best of 3 passes
    let run = |ix: &ShardedIndex, window: usize| -> (f64, f64) {
        let mut got: Vec<Vec<u32>> = Vec::new();
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            got = ds
                .test_queries
                .iter()
                .map(|v| {
                    let q = Query::new(v).k(k).window(window).rerank_window(window);
                    ix.search_scatter(&ix.model().project_query(v), &q).ids
                })
                .collect();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        (
            ds.test_queries.len() as f64 / best.max(1e-9),
            recall_at_k(&got, truth, k),
        )
    };
    let (qps1, recall1) = run(&one, WINDOW);
    println!("shards=1: window {WINDOW:<3} recall@{k} {recall1:.3}  {qps1:>8.0} QPS");
    // per-shard window sweep: each shard covers n/4 vectors, so the
    // smallest window whose union recall matches shards=1 wins
    let (mut w4, mut qps4, mut recall4) = (WINDOW, 0.0, 0.0);
    for w in [WINDOW / 3, WINDOW / 2, 2 * WINDOW / 3, WINDOW] {
        let (q, r) = run(&four, w);
        (w4, qps4, recall4) = (w, q, r);
        println!(
            "shards={SHARDS}: window {w:<3} recall@{k} {r:.3}  {q:>8.0} QPS  ({:.2}x)",
            q / qps1.max(1e-9)
        );
        if r >= recall1 - 0.005 {
            break;
        }
    }
    let speedup = qps4 / qps1.max(1e-9);
    println!(
        "sharded speedup at matched recall: {speedup:.2}x \
         (shards={SHARDS} w={w4} recall {recall4:.3} vs shards=1 w={WINDOW} recall {recall1:.3})"
    );
    Json::obj(vec![
        ("shards", Json::num(SHARDS as f64)),
        ("window_1shard", Json::num(WINDOW as f64)),
        ("window_per_shard", Json::num(w4 as f64)),
        ("k", Json::num(k as f64)),
        ("qps_1shard", Json::num(qps1)),
        ("qps_sharded", Json::num(qps4)),
        ("recall_1shard", Json::num(recall1)),
        ("recall_sharded", Json::num(recall4)),
        ("speedup_at_matched_recall", Json::num(speedup)),
    ])
}

/// Bigger-than-RAM serving arm: the same index served three ways —
/// fully owned in RAM, mmap-backed with a warm page cache, and
/// mmap-backed under an emulated memory cap of file_bytes/4 (every
/// query batch is followed by `evict_mapped`, which drops the mapping's
/// resident pages, so ~each pass refaults from disk the way a process
/// whose resident set is capped at a quarter of the index would).
/// Recall must be identical across all three — mmap changes where bytes
/// live, never what they say. Resident-set numbers come from
/// /proc/self/status. Returns the JSON fragment embedded under `"mmap"`
/// in BENCH_search.json.
fn bench_mmap(
    ds: &leanvec::data::synth::Dataset,
    gp: GraphParams,
    truth: &[Vec<u32>],
    k: usize,
) -> Json {
    use leanvec::graph::beam::SearchCtx;

    const WINDOW: usize = 60;
    let status_kib = |key: &str| -> f64 {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find(|l| l.starts_with(key))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse::<f64>().ok())
            })
            .unwrap_or(0.0)
    };

    let index = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(160)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .graph_params(gp)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let path = std::env::temp_dir().join(format!("leanvec-bench-mmap-{}.leanvec", std::process::id()));
    let file_bytes = index.save(&path, &SnapshotMeta::default()).expect("snapshot save");
    let mem_cap = file_bytes / 4;
    println!(
        "\n== mmap serving ({:.1} MiB snapshot, emulated cap {:.1} MiB) ==",
        file_bytes as f64 / (1024.0 * 1024.0),
        mem_cap as f64 / (1024.0 * 1024.0)
    );

    let reqs: Vec<Query> = ds
        .test_queries
        .iter()
        .map(|q| Query::new(q).k(k).window(WINDOW))
        .collect();
    // closed loop, one reused ctx, best of `passes`; `evict` drops the
    // mapping's pages after every 64-query batch
    let run = |ix: &LeanVecIndex, evict: bool, passes: usize| -> (f64, f64) {
        let mut ctx = SearchCtx::new(ix.len());
        let mut got: Vec<Vec<u32>> = Vec::new();
        let mut best = f64::INFINITY;
        for _ in 0..passes {
            if evict {
                ix.evict_mapped();
            }
            let t0 = std::time::Instant::now();
            got.clear();
            for (i, q) in reqs.iter().enumerate() {
                if evict && i % 64 == 63 {
                    ix.evict_mapped();
                }
                got.push(ix.search(&mut ctx, q).ids);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        (reqs.len() as f64 / best.max(1e-9), recall_at_k(&got, truth, k))
    };

    let (qps_owned, recall_owned) = run(&index, false, 3);
    let rss_before = status_kib("VmRSS:");
    let (mapped, _) = LeanVecIndex::load_mmap(&path).expect("mmap load");
    assert!(mapped.is_mapped());
    let (qps_warm, recall_warm) = run(&mapped, false, 3);
    let rss_warm = status_kib("VmRSS:");
    let (qps_capped, recall_capped) = run(&mapped, true, 2);
    let vm_hwm = status_kib("VmHWM:");
    println!(
        "owned  : {qps_owned:>8.0} QPS  recall@{k} {recall_owned:.3}\n\
         mmap   : {qps_warm:>8.0} QPS  recall@{k} {recall_warm:.3}  (warm cache)\n\
         capped : {qps_capped:>8.0} QPS  recall@{k} {recall_capped:.3}  (evict every 64 queries)\n\
         rss: {:.1} -> {:.1} MiB mapped-warm, peak {:.1} MiB",
        rss_before / 1024.0,
        rss_warm / 1024.0,
        vm_hwm / 1024.0
    );
    assert_eq!(recall_owned, recall_warm, "mmap serving changed recall");
    assert_eq!(recall_warm, recall_capped, "eviction changed recall");
    std::fs::remove_file(&path).ok();
    Json::obj(vec![
        ("snapshot_bytes", Json::num(file_bytes as f64)),
        ("emulated_cap_bytes", Json::num(mem_cap as f64)),
        ("window", Json::num(WINDOW as f64)),
        ("k", Json::num(k as f64)),
        ("qps_owned", Json::num(qps_owned)),
        ("qps_mmap_warm", Json::num(qps_warm)),
        ("qps_mmap_capped", Json::num(qps_capped)),
        ("recall_at_k", Json::num(recall_capped)),
        ("vm_rss_warm_kib", Json::num(rss_warm)),
        ("vm_hwm_kib", Json::num(vm_hwm)),
    ])
}

fn bench_search_baseline(
    ds: &leanvec::data::synth::Dataset,
    gp: GraphParams,
    truth: &[Vec<u32>],
    k: usize,
    sharded: Json,
    mmap: Json,
    engine: Json,
    overload: Json,
) {
    use leanvec::graph::beam::SearchCtx;
    use leanvec::index::flat::FlatIndex;

    const WINDOW: usize = 60;
    println!(
        "\n== search baseline (window {WINDOW}, kernel dispatch: {}) ==",
        leanvec::simd::active_features()
    );
    let index = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(160)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .graph_params(gp)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);

    let reqs: Vec<Query> = ds
        .test_queries
        .iter()
        .map(|q| Query::new(q).k(k).window(WINDOW))
        .collect();

    // single-thread sequential: one reused ctx, best of 3 passes
    let mut ctx = SearchCtx::new(index.len());
    let mut got: Vec<Vec<u32>> = Vec::new();
    let mut best_wall = f64::INFINITY;
    for _ in 0..3 {
        let t0 = std::time::Instant::now();
        got = reqs.iter().map(|q| index.search(&mut ctx, q).ids).collect();
        best_wall = best_wall.min(t0.elapsed().as_secs_f64());
    }
    let qps_seq = reqs.len() as f64 / best_wall.max(1e-9);
    let recall = recall_at_k(&got, truth, k);

    // all-core closed-loop batch
    let t0 = std::time::Instant::now();
    let batch: Vec<Vec<u32>> = index
        .search_batch(&reqs, 0)
        .into_iter()
        .map(|r| r.ids)
        .collect();
    let qps_batch = reqs.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let recall_batch = recall_at_k(&batch, truth, k);

    // flat full-scan point (the blocked linear-scan path)
    let flat = FlatIndex::new(&ds.database, ds.similarity);
    let n_flat = reqs.len().min(64);
    let t0 = std::time::Instant::now();
    for q in reqs.iter().take(n_flat) {
        std::hint::black_box(flat.search_one(q));
    }
    let flat_qps = n_flat as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    println!(
        "leanvec-ood-d160/lvq8: {qps_seq:.0} QPS (1 thread), {qps_batch:.0} QPS (batch), \
         recall@{k} {recall:.3} | flat scan {flat_qps:.0} QPS"
    );

    let out = Json::obj(vec![
        ("dataset", Json::str(&ds.name)),
        ("n", Json::num(ds.database.len() as f64)),
        ("dim", Json::num(ds.dim as f64)),
        ("target_dim", Json::num(160.0)),
        ("kernel_dispatch", Json::str(leanvec::simd::active_features())),
        ("window", Json::num(WINDOW as f64)),
        ("k", Json::num(k as f64)),
        ("queries", Json::num(reqs.len() as f64)),
        ("qps_1thread", Json::num(qps_seq)),
        ("qps_batch_all_cores", Json::num(qps_batch)),
        ("recall_at_k", Json::num(recall)),
        ("recall_at_k_batch", Json::num(recall_batch)),
        ("flat_scan_qps", Json::num(flat_qps)),
        ("sharded", sharded),
        ("mmap", mmap),
        ("engine", engine),
        ("overload", overload),
    ]);
    match std::fs::write("BENCH_search.json", out.to_pretty()) {
        Ok(()) => println!("[saved BENCH_search.json]"),
        Err(e) => eprintln!("could not write BENCH_search.json: {e}"),
    }
}

/// Serving-engine closed loop, run twice: once with the telemetry
/// registry disabled (`LEANVEC_NO_TELEMETRY`-equivalent) and once with
/// it enabled. The gap between the two is the whole-path cost of the
/// observability layer — stage timers, histograms, flight recorder —
/// and is the number the acceptance gate bounds (<= 3% QPS).
/// Per-stage and e2e tail latencies come from the enabled arm.
fn bench_engine(ds: &leanvec::data::synth::Dataset, gp: GraphParams, k: usize) -> Json {
    println!("\n== serving engine + telemetry A/B ==");
    let index = Arc::new(
        IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(160)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity),
    );
    let queries: Vec<Vec<f32>> = (0..2_000)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let cfg = EngineConfig {
        workers: 1,
        batch: BatchPolicy::default(),
        search: SearchParams {
            window: 60,
            rerank_window: 60,
        },
        ..Default::default()
    };

    // telemetry-off arm first so the warm-up run is the one we don't
    // report latencies from
    leanvec::obs::set_enabled(false);
    let (_r, report_off) =
        Engine::run_workload(Arc::clone(&index), cfg.clone(), &queries, k, None);
    let qps_off = report_off.metrics.qps;

    leanvec::obs::set_enabled(true);
    let (_r, report) = Engine::run_workload(index, cfg, &queries, k, None);
    let m = &report.metrics;

    let overhead_pct = if qps_off > 0.0 {
        (1.0 - m.qps / qps_off) * 100.0
    } else {
        0.0
    };
    println!("serving engine (telemetry on): {}", m);
    println!(
        "telemetry overhead: {qps_off:.0} QPS off vs {:.0} QPS on ({overhead_pct:+.1}%)",
        m.qps
    );

    Json::obj(vec![
        ("queries", Json::num(queries.len() as f64)),
        ("qps", Json::num(m.qps)),
        ("qps_telemetry_off", Json::num(qps_off)),
        ("telemetry_overhead_pct", Json::num(overhead_pct)),
        ("e2e_p50_ms", Json::num(m.latency_p50_ms)),
        ("e2e_p99_ms", Json::num(m.latency_p99_ms)),
        ("e2e_p999_ms", Json::num(m.latency_p999_ms)),
        ("queue_p50_ms", Json::num(m.stages.queue.p50)),
        ("queue_p99_ms", Json::num(m.stages.queue.p99)),
        ("project_p50_ms", Json::num(m.stages.project.p50)),
        ("project_p99_ms", Json::num(m.stages.project.p99)),
        ("search_p50_ms", Json::num(m.stages.search.p50)),
        ("search_p99_ms", Json::num(m.stages.search.p99)),
        ("merge_p50_ms", Json::num(m.stages.merge.p50)),
        ("merge_p99_ms", Json::num(m.stages.merge.p99)),
    ])
}

/// Overload arm: measure closed-loop capacity, then offer 3x that
/// rate open-loop with shedding off vs on. Overload handling is judged
/// on goodput (deadline-met answers per second of wall time), shed
/// rate, timeout rate, and the latency p99 of the *survivors* — under
/// overload what matters is the answers you did serve, not the ones
/// you refused at the door. Returns the JSON fragment embedded under
/// `"overload"` in BENCH_search.json.
fn bench_overload(ds: &leanvec::data::synth::Dataset, gp: GraphParams, k: usize) -> Json {
    use leanvec::coordinator::{EngineError, QuerySpec, ShedPolicy};

    println!("\n== overload shedding (3x capacity, open loop) ==");
    let index = Arc::new(
        IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(160)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity),
    );
    let search = SearchParams {
        window: 60,
        rerank_window: 60,
    };
    let workers = 2usize;

    // 1. capacity calibration: closed loop, the drain is the back-pressure
    let calib: Vec<Vec<f32>> = (0..2_000)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let (_r, report) = Engine::run_workload(
        Arc::clone(&index),
        EngineConfig {
            workers,
            search,
            ..Default::default()
        },
        &calib,
        k,
        None,
    );
    let capacity_qps = report.metrics.qps.max(1.0);
    let deadline_ms = (4.0 * report.metrics.latency_p99_ms).clamp(20.0, 250.0) as u64;
    let offered_qps = 3.0 * capacity_qps;
    println!(
        "capacity {capacity_qps:.0} QPS closed-loop (p99 {:.2} ms) -> \
         offering {offered_qps:.0} QPS, deadline {deadline_ms} ms",
        report.metrics.latency_p99_ms
    );

    // the depth bound is the backlog that can still make its deadline
    // (capacity x deadline); the wait bound trips at half the deadline
    // so survivors still have search budget left after queueing
    let shed_on = ShedPolicy {
        max_queue_depth: ((capacity_qps * deadline_ms as f64 / 1000.0) as usize).max(8),
        max_queue_wait_ms: (deadline_ms / 2).max(1),
    };

    // 2. open-loop arms: the arrival clock never waits for the engine
    // (that is the whole point of open-loop overload)
    let run_open = |label: &str, shed: ShedPolicy| -> (Json, f64) {
        let engine = Engine::start(
            Arc::clone(&index),
            EngineConfig {
                workers,
                search,
                shed,
                ..Default::default()
            },
        );
        let n = (offered_qps * 2.0) as usize; // ~2 s of offered load
        let interval = 1.0 / offered_qps;
        let (mut admitted, mut shed_count) = (0usize, 0usize);
        let t0 = std::time::Instant::now();
        for i in 0..n {
            let target = i as f64 * interval;
            let mut now = t0.elapsed().as_secs_f64();
            while now < target {
                if target - now > 500e-6 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(target - now));
                } else {
                    std::hint::spin_loop();
                }
                now = t0.elapsed().as_secs_f64();
            }
            let q = ds.test_queries[i % ds.test_queries.len()].clone();
            match engine.submit_spec(q, QuerySpec::top_k(k).with_timeout_ms(deadline_ms)) {
                Ok(_) => admitted += 1,
                Err(EngineError::Overloaded { .. }) => shed_count += 1,
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        let responses = engine.drain(admitted);
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        engine.shutdown();
        assert_eq!(responses.len(), admitted, "every admitted request resolves");
        let mut survivor_ms: Vec<f64> = responses
            .iter()
            .filter(|r| r.is_ok())
            .map(|r| r.latency_s * 1_000.0)
            .collect();
        survivor_ms.sort_by(f64::total_cmp);
        let timeouts = admitted - survivor_ms.len();
        let goodput = survivor_ms.len() as f64 / wall;
        let p99 = if survivor_ms.is_empty() {
            0.0
        } else {
            survivor_ms[((survivor_ms.len() as f64 * 0.99) as usize).min(survivor_ms.len() - 1)]
        };
        println!(
            "{label:<8}: offered {n}, shed {shed_count} ({:.0}%), timed out {timeouts} ({:.0}%), \
             goodput {goodput:.0} QPS ({:.2}x capacity), survivor p99 {p99:.2} ms",
            100.0 * shed_count as f64 / n.max(1) as f64,
            100.0 * timeouts as f64 / n.max(1) as f64,
            goodput / capacity_qps
        );
        let frag = Json::obj(vec![
            ("offered", Json::num(n as f64)),
            ("admitted", Json::num(admitted as f64)),
            ("shed", Json::num(shed_count as f64)),
            ("timed_out", Json::num(timeouts as f64)),
            ("shed_rate", Json::num(shed_count as f64 / n.max(1) as f64)),
            ("timeout_rate", Json::num(timeouts as f64 / n.max(1) as f64)),
            ("goodput_qps", Json::num(goodput)),
            ("survivor_p99_ms", Json::num(p99)),
            ("wall_seconds", Json::num(wall)),
        ]);
        (frag, goodput)
    };

    let (off, goodput_off) = run_open("shed off", ShedPolicy::default());
    let (on, goodput_on) = run_open("shed on", shed_on);
    let ratio = goodput_on / goodput_off.max(1e-9);
    println!("shedding goodput ratio at 3x offered load: {ratio:.2}x");

    Json::obj(vec![
        ("capacity_qps", Json::num(capacity_qps)),
        ("offered_qps", Json::num(offered_qps)),
        ("overload_factor", Json::num(3.0)),
        ("deadline_ms", Json::num(deadline_ms as f64)),
        ("max_queue_depth", Json::num(shed_on.max_queue_depth as f64)),
        ("max_queue_wait_ms", Json::num(shed_on.max_queue_wait_ms as f64)),
        ("goodput_ratio_on_vs_off", Json::num(ratio)),
        ("shed_off", off),
        ("shed_on", on),
    ])
}

/// Churn phase: streaming mutation throughput on a live index, search
/// tail latency under 10% churn, and consolidation wall time — emitted
/// machine-readable to `BENCH_mutate.json`.
fn bench_churn(ds: &leanvec::data::synth::Dataset, gp: GraphParams) {
    println!("\n== live mutation churn ==");
    let index = IndexBuilder::new()
        .projection(ProjectionKind::OodEigSearch)
        .target_dim(160)
        .graph_params(gp)
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let n0 = index.len();
    let dim = ds.dim;
    let live = Arc::new(LiveIndex::from_index(index));
    let churn = (n0 / 10).max(1);
    let mut rng = Rng::new(0xCAFE);
    let new_vecs: Vec<Vec<f32>> = (0..churn)
        .map(|_| {
            let base = &ds.database[rng.below(n0)];
            base.iter().map(|&x| x + 0.05 * rng.gaussian_f32()).collect()
        })
        .collect();

    // --- direct (unloaded) mutation throughput
    let t0 = std::time::Instant::now();
    for (i, v) in new_vecs.iter().enumerate() {
        live.insert((n0 + i) as u32, v).expect("insert");
    }
    let insert_qps = churn as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let mut victims: Vec<u32> = (0..n0 as u32).collect();
    rng.shuffle(&mut victims);
    victims.truncate(churn);
    let t0 = std::time::Instant::now();
    for &id in &victims {
        live.delete(id).expect("delete");
    }
    let delete_qps = churn as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    let consolidate = live.consolidate();
    println!(
        "direct: {insert_qps:.0} inserts/s, {delete_qps:.0} deletes/s | \
         consolidation: {} removed, {} rewired in {:.3}s",
        consolidate.removed, consolidate.rewired, consolidate.seconds
    );

    // --- search latency while another 10% churns through the engine
    let cfg = EngineConfig {
        workers: 2,
        search: SearchParams {
            window: 60,
            rerank_window: 60,
        },
        consolidate_threshold: 0.08,
        ..EngineConfig::default()
    };
    let mut engine = Engine::start_live(Arc::clone(&live), cfg);
    let n_queries = 2000usize;
    let ext_base = (n0 + churn) as u32;
    let live_now = live.live_ids();
    let t0 = std::time::Instant::now();
    let mut mutated = 0usize;
    for i in 0..n_queries {
        if mutated < churn && mutated * n_queries <= i * churn {
            engine
                .submit_insert(ext_base + mutated as u32, new_vecs[mutated].clone())
                .expect("live engine running");
            engine
                .submit_delete(live_now[mutated * (live_now.len() / churn).max(1)])
                .expect("live engine running");
            mutated += 1;
        }
        engine
            .submit(ds.test_queries[i % ds.test_queries.len()].clone(), 10)
            .expect("engine running");
    }
    let responses = engine.drain(n_queries);
    engine.quiesce_mutations();
    let churn_wall = t0.elapsed().as_secs_f64();
    let stats = engine.ingest_stats();
    engine.shutdown();
    let metrics = Metrics::from_responses(&responses, churn_wall);
    println!("under churn: {metrics}");
    println!(
        "ingest under load: {} inserts + {} deletes, {} consolidations ({:.3}s)",
        stats.inserts, stats.deletes, stats.consolidations, stats.consolidate_seconds
    );

    let out = Json::obj(vec![
        ("n", Json::num(n0 as f64)),
        ("dim", Json::num(dim as f64)),
        ("churn_fraction", Json::num(0.1)),
        ("insert_qps", Json::num(insert_qps)),
        ("delete_qps", Json::num(delete_qps)),
        ("consolidate_removed", Json::num(consolidate.removed as f64)),
        ("consolidate_rewired", Json::num(consolidate.rewired as f64)),
        ("consolidate_seconds", Json::num(consolidate.seconds)),
        ("churn_queries", Json::num(n_queries as f64)),
        ("churn_search_qps", Json::num(metrics.qps)),
        ("churn_latency_p50_ms", Json::num(metrics.latency_p50_ms)),
        ("churn_latency_p99_ms", Json::num(metrics.latency_p99_ms)),
        (
            "churn_deleted_skipped_total",
            Json::num(metrics.query_stats.deleted_skipped_total as f64),
        ),
        (
            "churn_consolidations",
            Json::num(stats.consolidations as f64),
        ),
        (
            "churn_consolidate_seconds",
            Json::num(stats.consolidate_seconds),
        ),
    ]);
    match std::fs::write("BENCH_mutate.json", out.to_pretty()) {
        Ok(()) => println!("[saved BENCH_mutate.json]"),
        Err(e) => eprintln!("could not write BENCH_mutate.json: {e}"),
    }
}

fn main() {
    let mut spec = SynthSpec::ood("bench-e2e", 768, 6_000, 256);
    spec.seed = 0xBE;
    let ds = generate(&spec);
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let mut gp = GraphParams::for_similarity(ds.similarity);
    gp.max_degree = 32;
    gp.build_window = 64;
    println!("== bench_e2e: rqa-768-style, {} vectors ==", ds.database.len());

    let windows = [10usize, 20, 40, 80, 160, 300];
    let mut qps_ref: Option<f64> = None;
    for (name, proj, d, comp) in [
        ("fp16", ProjectionKind::None, 0usize, Compression::F16),
        ("lvq4x8", ProjectionKind::None, 0, Compression::Lvq4x8),
        ("leanvec-ood-d160", ProjectionKind::OodEigSearch, 160, Compression::Lvq8),
    ] {
        let index = IndexBuilder::new()
            .projection(proj)
            .target_dim(d)
            .primary(comp)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
        let curve = qps_recall_curve(&index, &ds.test_queries, &truth, k, &windows);
        let q90 = qps_at_recall(&curve, 0.9);
        let speedup = match (q90, qps_ref) {
            (Some(q), Some(r)) => format!("{:.1}x vs fp16", q / r),
            _ => String::new(),
        };
        if name == "fp16" {
            qps_ref = q90;
        }
        println!(
            "{name:<18} QPS@0.9recall = {}  {speedup}",
            q90.map(|q| format!("{q:.0}")).unwrap_or("-".into())
        );
        for p in &curve {
            println!(
                "    w={:<4} recall {:.3}  {:>8.0} QPS  {:>8.0} B/query",
                p.window, p.recall, p.qps, p.bytes_per_query
            );
        }
    }

    // serving engine closed loop + telemetry overhead A/B (embedded
    // into BENCH_search.json)
    let engine_arm = bench_engine(&ds, gp, k);

    // overload shedding at 3x capacity (embedded into BENCH_search.json)
    let overload_arm = bench_overload(&ds, gp, k);

    // sharded scatter-gather arm (embedded into BENCH_search.json)
    let sharded = bench_sharded(&ds, gp, &truth, k);

    // bigger-than-RAM mmap serving arm (embedded into BENCH_search.json)
    let mmap = bench_mmap(&ds, gp, &truth, k);

    // fixed-window search QPS + recall anchor -> BENCH_search.json
    bench_search_baseline(&ds, gp, &truth, k, sharded, mmap, engine_arm, overload_arm);

    // parallel build speedup trajectory -> BENCH_build.json
    bench_build_trajectory(&ds, gp, &truth, k);

    // streaming mutation churn -> BENCH_mutate.json
    bench_churn(&ds, gp);

    // roll this run's headline numbers into the committed trajectory
    roll_history();
}

/// Append this run's headline numbers to `BENCH_history.json` — the
/// committed per-PR perf trajectory. Each entry is a compact summary
/// of the three BENCH_*.json files (which hold the full detail for one
/// run only and get overwritten every time). Label via $BENCH_LABEL,
/// defaulting to run-<n>.
fn roll_history() {
    let read = |p: &str| {
        std::fs::read_to_string(p)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
    };
    let pick = |j: &Option<Json>, keys: &[&str]| -> f64 {
        let mut cur = match j {
            Some(j) => j,
            None => return 0.0,
        };
        for key in keys {
            cur = match cur.get(key) {
                Some(next) => next,
                None => return 0.0,
            };
        }
        cur.as_f64().unwrap_or(0.0)
    };
    let build = read("BENCH_build.json");
    let search = read("BENCH_search.json");
    let mutate = read("BENCH_mutate.json");
    // fastest build in the trajectory sweep (the all-cores row)
    let best_build = build
        .as_ref()
        .and_then(|b| b.get("builds"))
        .and_then(|b| b.as_arr())
        .map(|rows| {
            rows.iter()
                .filter_map(|r| r.get("total_seconds").and_then(|v| v.as_f64()))
                .fold(f64::INFINITY, f64::min)
        })
        .filter(|v| v.is_finite())
        .unwrap_or(0.0);
    let mut entries: Vec<Json> = std::fs::read_to_string("BENCH_history.json")
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    let label = std::env::var("BENCH_LABEL")
        .unwrap_or_else(|_| format!("run-{}", entries.len() + 1));
    let unix_seconds = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0);
    entries.push(Json::obj(vec![
        ("label", Json::str(&label)),
        ("unix_seconds", Json::num(unix_seconds)),
        ("search_qps_1thread", Json::num(pick(&search, &["qps_1thread"]))),
        (
            "search_qps_batch_all_cores",
            Json::num(pick(&search, &["qps_batch_all_cores"])),
        ),
        ("search_recall_at_k", Json::num(pick(&search, &["recall_at_k"]))),
        (
            "sharded_qps_1shard",
            Json::num(pick(&search, &["sharded", "qps_1shard"])),
        ),
        (
            "sharded_qps_sharded",
            Json::num(pick(&search, &["sharded", "qps_sharded"])),
        ),
        (
            "sharded_speedup_at_matched_recall",
            Json::num(pick(&search, &["sharded", "speedup_at_matched_recall"])),
        ),
        (
            "mmap_qps_warm",
            Json::num(pick(&search, &["mmap", "qps_mmap_warm"])),
        ),
        (
            "mmap_qps_capped",
            Json::num(pick(&search, &["mmap", "qps_mmap_capped"])),
        ),
        (
            "mmap_vm_hwm_kib",
            Json::num(pick(&search, &["mmap", "vm_hwm_kib"])),
        ),
        ("engine_qps", Json::num(pick(&search, &["engine", "qps"]))),
        (
            "telemetry_overhead_pct",
            Json::num(pick(&search, &["engine", "telemetry_overhead_pct"])),
        ),
        (
            "engine_e2e_p99_ms",
            Json::num(pick(&search, &["engine", "e2e_p99_ms"])),
        ),
        (
            "overload_goodput_ratio_on_vs_off",
            Json::num(pick(&search, &["overload", "goodput_ratio_on_vs_off"])),
        ),
        (
            "overload_goodput_shed_on_qps",
            Json::num(pick(&search, &["overload", "shed_on", "goodput_qps"])),
        ),
        (
            "overload_shed_rate",
            Json::num(pick(&search, &["overload", "shed_on", "shed_rate"])),
        ),
        (
            "overload_survivor_p99_ms",
            Json::num(pick(&search, &["overload", "shed_on", "survivor_p99_ms"])),
        ),
        ("build_best_total_seconds", Json::num(best_build)),
        (
            "build_speedup_parallel_phases",
            Json::num({
                let b = build
                    .as_ref()
                    .and_then(|b| b.get("builds"))
                    .and_then(|b| b.as_arr());
                b.and_then(|rows| rows.last())
                    .map(|r| pick(&Some(r.clone()), &["speedup_parallel_phases_vs_serial"]))
                    .unwrap_or(0.0)
            }),
        ),
        ("mutate_insert_qps", Json::num(pick(&mutate, &["insert_qps"]))),
        ("mutate_delete_qps", Json::num(pick(&mutate, &["delete_qps"]))),
        (
            "mutate_churn_search_qps",
            Json::num(pick(&mutate, &["churn_search_qps"])),
        ),
        (
            "mutate_churn_latency_p99_ms",
            Json::num(pick(&mutate, &["churn_latency_p99_ms"])),
        ),
    ]));
    match std::fs::write("BENCH_history.json", Json::arr(entries).to_pretty()) {
        Ok(()) => println!("[rolled BENCH_history.json]"),
        Err(e) => eprintln!("could not write BENCH_history.json: {e}"),
    }
}
