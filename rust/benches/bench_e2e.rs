//! End-to-end bench: the paper's headline comparison (Fig. 1 / Fig. 5)
//! at bench scale — QPS at matched recall across representations, plus
//! the serving engine's throughput.

use leanvec::config::{Compression, GraphParams, ProjectionKind};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig};
use leanvec::data::gt::ground_truth;
use leanvec::data::synth::{generate, SynthSpec};
use leanvec::experiments::harness::{qps_at_recall, qps_recall_curve};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use std::sync::Arc;

fn main() {
    let mut spec = SynthSpec::ood("bench-e2e", 768, 6_000, 256);
    spec.seed = 0xBE;
    let ds = generate(&spec);
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let mut gp = GraphParams::for_similarity(ds.similarity);
    gp.max_degree = 32;
    gp.build_window = 64;
    println!("== bench_e2e: rqa-768-style, {} vectors ==", ds.database.len());

    let windows = [10usize, 20, 40, 80, 160, 300];
    let mut qps_ref: Option<f64> = None;
    for (name, proj, d, comp) in [
        ("fp16", ProjectionKind::None, 0usize, Compression::F16),
        ("lvq4x8", ProjectionKind::None, 0, Compression::Lvq4x8),
        ("leanvec-ood-d160", ProjectionKind::OodEigSearch, 160, Compression::Lvq8),
    ] {
        let index = IndexBuilder::new()
            .projection(proj)
            .target_dim(d)
            .primary(comp)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
        let curve = qps_recall_curve(&index, &ds.test_queries, &truth, k, &windows);
        let q90 = qps_at_recall(&curve, 0.9);
        let speedup = match (q90, qps_ref) {
            (Some(q), Some(r)) => format!("{:.1}x vs fp16", q / r),
            _ => String::new(),
        };
        if name == "fp16" {
            qps_ref = q90;
        }
        println!(
            "{name:<18} QPS@0.9recall = {}  {speedup}",
            q90.map(|q| format!("{q:.0}")).unwrap_or("-".into())
        );
        for p in &curve {
            println!(
                "    w={:<4} recall {:.3}  {:>8.0} QPS  {:>8.0} B/query",
                p.window, p.recall, p.qps, p.bytes_per_query
            );
        }
    }

    // serving engine throughput (closed loop)
    let index = Arc::new(
        IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(160)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity),
    );
    let queries: Vec<Vec<f32>> = (0..2_000)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let cfg = EngineConfig {
        workers: 1,
        batch: BatchPolicy::default(),
        search: SearchParams {
            window: 60,
            rerank_window: 60,
        },
        ..Default::default()
    };
    let (_r, report) = Engine::run_workload(index, cfg, &queries, k, None);
    println!("\nserving engine: {}", report.metrics);
}
