//! Runtime benches: PJRT artifact dispatch vs native — quantifies (a)
//! why batched projection can go through PJRT, (b) why per-vector
//! scoring stays native (dispatch overhead dwarfs a single fused dot —
//! the same argument the paper makes against batched-ADC methods for
//! graph search), and (c) the pallas-interpret vs jnp-XLA lowering gap
//! (EXPERIMENTS.md §Perf).

use leanvec::index::builder::{BatchProjector, NativeProjector};
use leanvec::leanvec::fw::{FwStepper, NativeStepper};
use leanvec::linalg::Matrix;
use leanvec::runtime::client::{lit_from_f32s, lit_from_matrix, lit_from_u8};
use leanvec::runtime::default_artifacts_dir;
use leanvec::util::rng::Rng;
use leanvec::util::stats::bench;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(400);
    let Ok(rt) = leanvec::runtime::executor::open_shared(&default_artifacts_dir()) else {
        println!("bench_runtime: artifacts not built; skipping");
        return;
    };
    println!("== bench_runtime: PJRT vs native ==");
    let mut rng = Rng::new(1);
    let (dd, d) = (256usize, 96usize);

    // ---- batch projection: PJRT vs native (1024-column batches)
    let p = Matrix::randn(d, dd, &mut rng);
    let rows: Vec<Vec<f32>> = (0..1024)
        .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
        .collect();
    let mut pjrt_proj = leanvec::runtime::PjrtProjector::new(rt.clone());
    let r = bench("project-1024/pjrt", budget, || {
        std::hint::black_box(pjrt_proj.project(&p, &rows));
    });
    println!("{r}  ({:.1} ns/vector)", r.mean_ns / 1024.0);
    let mut native_proj = NativeProjector;
    let r = bench("project-1024/native", budget, || {
        std::hint::black_box(native_proj.project(&p, &rows));
    });
    println!("{r}  ({:.1} ns/vector)", r.mean_ns / 1024.0);

    // ---- fw_step: PJRT (xla lowering) vs native
    let kq = Matrix::randn(600, dd, &mut rng).second_moment();
    let kx = Matrix::randn(600, dd, &mut rng).second_moment();
    let a0 = leanvec::linalg::qr::random_orthonormal(d, dd, &mut rng);
    let mut pjrt_fw = leanvec::runtime::PjrtFwStepper::new(rt.clone());
    let r = bench("fw_step/pjrt-xla", budget, || {
        std::hint::black_box(pjrt_fw.step(&a0, &a0, &kq, &kx, 0.5));
    });
    println!("{r}");
    let r = bench("fw_step/native", budget, || {
        std::hint::black_box(NativeStepper.step(&a0, &a0, &kq, &kx, 0.5));
    });
    println!("{r}");

    // ---- fused LVQ scoring: one PJRT dispatch of a 1024-block vs the
    //      native per-vector loop over the same block
    let spec = {
        let b = rt.borrow();
        b.manifest().find("score_batch", dd, d).cloned()
    };
    if let Some(spec) = spec {
        let n = spec.batch.unwrap();
        let codes: Vec<u8> = (0..n * d).map(|_| rng.below(256) as u8).collect();
        let delta: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.01 + 1e-4).collect();
        let lo: Vec<f32> = (0..n).map(|_| rng.gaussian_f32() * 0.01).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let qstats = [q.iter().sum::<f32>(), 0.0f32];
        let q_col = Matrix::from_vec(d, 1, q.clone());

        let r = bench(&format!("score-{n}/pjrt-pallas"), budget, || {
            let mut b = rt.borrow_mut();
            let out = b
                .execute(
                    &spec.name,
                    &[
                        lit_from_u8(n, d, &codes).unwrap(),
                        lit_from_f32s(&delta).unwrap(),
                        lit_from_f32s(&lo).unwrap(),
                        lit_from_matrix(&q_col).unwrap(),
                        lit_from_f32s(&qstats).unwrap(),
                    ],
                )
                .unwrap();
            std::hint::black_box(out);
        });
        println!("{r}  ({:.1} ns/vector)", r.mean_ns / n as f64);

        let r = bench(&format!("score-{n}/native"), budget, || {
            let mut acc = 0.0f32;
            for i in 0..n {
                let code_dot: f32 = codes[i * d..(i + 1) * d]
                    .iter()
                    .zip(q.iter())
                    .map(|(&c, &qv)| c as f32 * qv)
                    .sum();
                acc += delta[i] * code_dot + lo[i] * qstats[0];
            }
            std::hint::black_box(acc);
        });
        println!("{r}  ({:.1} ns/vector)", r.mean_ns / n as f64);
    }
}
