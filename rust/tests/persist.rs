//! Snapshot persistence suite: build → save → load → search must be
//! bit-identical to the in-memory index for every store kind, and every
//! corruption mode (bad magic, version skew, truncation, bit rot,
//! missing sections) must fail loudly without panicking.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::LeanVecIndex;
use leanvec::index::persist::{self, RawSection, SnapshotError, SnapshotMeta};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::util::rng::Rng;
use std::path::PathBuf;

fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("leanvec-persist-{}-{name}", std::process::id()))
}

fn build(
    primary: Compression,
    secondary: Compression,
    sim: Similarity,
    proj: ProjectionKind,
    seed: u64,
) -> LeanVecIndex {
    let x = rows(250, 16, seed);
    let q = rows(60, 16, seed + 1);
    let mut gp = GraphParams::for_similarity(sim);
    gp.max_degree = 16;
    gp.build_window = 40;
    let d = if proj == ProjectionKind::None { 0 } else { 6 };
    IndexBuilder::new()
        .projection(proj)
        .target_dim(d)
        .primary(primary)
        .secondary(secondary)
        .graph_params(gp)
        .seed(77)
        .build(&x, Some(&q), sim)
}

/// Assert that `loaded` answers exactly like `built`: ids, score bits,
/// and the full `QueryStats` accounting, over `trials` fresh queries.
fn assert_search_identical(built: &LeanVecIndex, loaded: &LeanVecIndex, trials: usize, seed: u64) {
    assert_eq!(loaded.len(), built.len());
    assert_eq!(loaded.sim, built.sim);
    assert_eq!(loaded.primary_compression, built.primary_compression);
    assert_eq!(loaded.secondary_compression, built.secondary_compression);
    assert_eq!(loaded.graph.medoid, built.graph.medoid);
    let mut rng = Rng::new(seed);
    let mut ctx_a = SearchCtx::new(built.len());
    let mut ctx_b = SearchCtx::new(loaded.len());
    let dd = built.model.input_dim();
    for _ in 0..trials {
        let q: Vec<f32> = (0..dd).map(|_| rng.gaussian_f32()).collect();
        let query = Query::new(&q).k(10).window(30);
        let a = built.search(&mut ctx_a, &query);
        let b = loaded.search(&mut ctx_b, &query);
        assert_eq!(a.ids, b.ids);
        let bits = |s: &[f32]| s.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a.scores), bits(&b.scores), "scores not bit-identical");
        assert_eq!(a.stats, b.stats, "QueryStats diverged");
    }
}

#[test]
fn round_trip_bit_identical_across_store_kinds() {
    let arms: [(Compression, Compression, Similarity, ProjectionKind); 6] = [
        (Compression::Lvq8, Compression::F16, Similarity::InnerProduct, ProjectionKind::Id),
        (Compression::Lvq4, Compression::F16, Similarity::L2, ProjectionKind::Id),
        (Compression::Lvq4x8, Compression::F16, Similarity::InnerProduct, ProjectionKind::OodEigSearch),
        (Compression::F16, Compression::F32, Similarity::L2, ProjectionKind::Id),
        (Compression::F32, Compression::Lvq4x8, Similarity::InnerProduct, ProjectionKind::Id),
        // identity projection (d == D) and the cosine-normalization path
        (Compression::Lvq8, Compression::F16, Similarity::Cosine, ProjectionKind::None),
    ];
    for (i, (p, s, sim, proj)) in arms.into_iter().enumerate() {
        let built = build(p, s, sim, proj, 100 + i as u64);
        let path = tmp(&format!("roundtrip-{i}.leanvec"));
        let meta_in = SnapshotMeta {
            dataset: "synthetic-test".into(),
            seed: 0xFEED_FACE_CAFE_F00D,
            scale: 0.25,
            ..SnapshotMeta::default()
        };
        built.save(&path, &meta_in).expect("save");
        let (loaded, meta_out) = LeanVecIndex::load(&path).expect("load");
        assert_eq!(meta_out.dataset, "synthetic-test");
        assert_eq!(meta_out.seed, 0xFEED_FACE_CAFE_F00D, "u64 seed survives");
        assert_eq!(meta_out.scale, 0.25);
        assert_search_identical(&built, &loaded, 15, 500 + i as u64);
        // build provenance travels with the file
        assert_eq!(
            loaded.build_breakdown.graph_seconds.to_bits(),
            built.build_breakdown.graph_seconds.to_bits()
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn snapshot_bytes_are_deterministic() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        42,
    );
    let (pa, pb) = (tmp("det-a.leanvec"), tmp("det-b.leanvec"));
    built.save(&pa, &SnapshotMeta::default()).unwrap();
    built.save(&pb, &SnapshotMeta::default()).unwrap();
    assert_eq!(std::fs::read(&pa).unwrap(), std::fs::read(&pb).unwrap());
    // overwriting an existing snapshot is atomic-by-rename: re-saving
    // succeeds and leaves no .tmp file behind
    built.save(&pa, &SnapshotMeta::default()).unwrap();
    let staging = PathBuf::from(format!("{}.tmp", pa.display()));
    assert!(!staging.exists(), "temp staging file left behind");
    std::fs::remove_file(&pa).ok();
    std::fs::remove_file(&pb).ok();
}

fn saved_snapshot(name: &str) -> (PathBuf, Vec<u8>) {
    let built = build(
        Compression::Lvq4x8,
        Compression::F16,
        Similarity::L2,
        ProjectionKind::Id,
        7,
    );
    let path = tmp(name);
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    (path, bytes)
}

#[test]
fn corrupted_magic_fails_loudly() {
    let (path, mut bytes) = saved_snapshot("badmagic.leanvec");
    bytes[0] = b'X';
    std::fs::write(&path, &bytes).unwrap();
    match LeanVecIndex::load(&path) {
        Err(SnapshotError::BadMagic) => {}
        Err(other) => panic!("expected BadMagic, got {other:?}"),
        Ok(_) => panic!("corrupted magic must not load"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn version_mismatch_fails_loudly() {
    let (path, mut bytes) = saved_snapshot("version.leanvec");
    bytes[8] = 0xFE; // format version -> 0xFE: a future incompatible rev
    std::fs::write(&path, &bytes).unwrap();
    match LeanVecIndex::load(&path) {
        Err(SnapshotError::UnsupportedVersion { found, supported }) => {
            assert_eq!(found, 0xFE);
            assert_eq!(supported, persist::FORMAT_VERSION);
        }
        Err(other) => panic!("expected UnsupportedVersion, got {other:?}"),
        Ok(_) => panic!("future version must not load"),
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn truncated_file_fails_loudly_at_every_length() {
    let (path, bytes) = saved_snapshot("trunc.leanvec");
    // a spread of cuts: inside the header, the table, and each payload
    let cuts = [
        0,
        7,
        12,
        15,
        20,
        100,
        bytes.len() / 4,
        bytes.len() / 2,
        bytes.len() - 1,
    ];
    for cut in cuts {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = match LeanVecIndex::load(&path) {
            Err(e) => e,
            Ok(_) => panic!("cut at {cut} must fail"),
        };
        match err {
            SnapshotError::Truncated(_)
            | SnapshotError::BadMagic
            | SnapshotError::ChecksumMismatch { .. } => {}
            other => panic!("cut {cut}: unexpected error {other:?}"),
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn payload_bit_rot_fails_checksum() {
    let (path, bytes) = saved_snapshot("bitrot.leanvec");
    // flip one byte in each quarter of the payload region
    let start = bytes.len() / 4;
    for pos in [start, bytes.len() / 2, bytes.len() - 2] {
        let mut rotted = bytes.clone();
        rotted[pos] ^= 0x5A;
        std::fs::write(&path, &rotted).unwrap();
        match LeanVecIndex::load(&path) {
            Err(SnapshotError::ChecksumMismatch { section }) => {
                assert!(!section.is_empty());
            }
            // a flip inside the section table corrupts offsets instead
            Err(SnapshotError::Truncated(_)) => {}
            Err(other) => panic!("pos {pos}: expected checksum failure, got {other:?}"),
            Ok(_) => panic!("pos {pos}: bit rot must not load"),
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn unknown_sections_are_ignored_forward_compatibly() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        13,
    );
    let path = tmp("fwdcompat.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    // a "newer writer" appends a section this reader does not know
    let mut sections = persist::read_sections(&path).unwrap();
    sections.push(RawSection::new(*b"SHARDMAP", vec![0xAB; 64]));
    persist::write_sections(&path, &sections).unwrap();
    let (loaded, _) = LeanVecIndex::load(&path).expect("unknown section must not break loading");
    assert_search_identical(&built, &loaded, 10, 900);
    std::fs::remove_file(&path).ok();
}

#[test]
fn missing_required_section_fails_loudly() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        14,
    );
    let path = tmp("missing.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let sections: Vec<RawSection> = persist::read_sections(&path)
        .unwrap()
        .into_iter()
        .filter(|s| s.tag != persist::SECTION_GRAPH)
        .collect();
    persist::write_sections(&path, &sections).unwrap();
    match LeanVecIndex::load(&path) {
        Err(SnapshotError::MissingSection(tag)) => assert_eq!(tag, "GRAPH"),
        Err(other) => panic!("expected MissingSection, got {other:?}"),
        Ok(_) => panic!("snapshot without GRAPH must not load"),
    }
    std::fs::remove_file(&path).ok();
}

/// The mutation contract for both load paths: a damaged snapshot either
/// fails with a typed [`SnapshotError`] or — when the mutation landed in
/// bytes no reader consumes, e.g. alignment padding — loads an index
/// that answers bit-identically to the pristine one. Never a panic,
/// never silently-wrong results.
fn assert_mutation_contract(
    path: &std::path::Path,
    mutated: &[u8],
    baseline: &LeanVecIndex,
    what: &str,
    seed: u64,
) {
    std::fs::write(path, mutated).unwrap();
    for mmap in [false, true] {
        let result = if mmap {
            LeanVecIndex::load_mmap(path)
        } else {
            LeanVecIndex::load(path)
        };
        match result {
            Err(e) => {
                // every variant renders; the error chain must not panic
                let _ = format!("{what} (mmap={mmap}): {e} / {e:?}");
                let _ = std::error::Error::source(&e);
            }
            Ok((idx, _)) => assert_search_identical(baseline, &idx, 3, seed),
        }
    }
}

#[test]
fn corruption_fuzz_battery_typed_error_or_bit_identical() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        18,
    );
    let scratch = tmp("fuzz.leanvec");
    built.save(&scratch, &SnapshotMeta::default()).unwrap();
    let bytes = std::fs::read(&scratch).unwrap();
    let (baseline, _) = LeanVecIndex::load(&scratch).unwrap();

    // deterministic seed: every CI run fuzzes the same mutations
    let mut rng = Rng::new(0xF00D_5EED);

    // single-bit flips spread over the whole file (header, table,
    // payloads, padding)
    for trial in 0..60u64 {
        let mut m = bytes.clone();
        let pos = rng.below(m.len());
        m[pos] ^= 1u8 << rng.below(8);
        assert_mutation_contract(&scratch, &m, &baseline, "bit flip", 2000 + trial);
    }

    // multi-byte stomp: overwrite a random short run with garbage
    for trial in 0..20u64 {
        let mut m = bytes.clone();
        let pos = rng.below(m.len());
        let run = 1 + rng.below(32.min(m.len() - pos));
        for b in &mut m[pos..pos + run] {
            *b = rng.next_u64() as u8;
        }
        assert_mutation_contract(&scratch, &m, &baseline, "stomp", 3000 + trial);
    }

    // truncations at random lengths
    for trial in 0..20u64 {
        let cut = rng.below(bytes.len());
        assert_mutation_contract(&scratch, &bytes[..cut], &baseline, "truncate", 4000 + trial);
    }

    // section-table surgery: swap the (offset, len) of two entries while
    // keeping their tags and CRCs — each tag now points at the other's
    // payload, which the per-section checksum must catch
    let count = u32::from_le_bytes(bytes[12..16].try_into().unwrap()) as usize;
    assert!(count >= 5, "core snapshot has five sections");
    const ENTRY: usize = 28;
    for (i, j) in [(0usize, 2usize), (2, 4), (1, 3)] {
        let mut m = bytes.clone();
        let (ei, ej) = (16 + i * ENTRY, 16 + j * ENTRY);
        let a: Vec<u8> = m[ei + 8..ei + 24].to_vec(); // offset + len
        let b: Vec<u8> = m[ej + 8..ej + 24].to_vec();
        m[ei + 8..ei + 24].copy_from_slice(&b);
        m[ej + 8..ej + 24].copy_from_slice(&a);
        assert_mutation_contract(&scratch, &m, &baseline, "table swap", 5000 + i as u64);
    }

    std::fs::remove_file(&scratch).ok();
}

/// Emulate the pre-alignment writer: identical header and table layout
/// but payloads packed back-to-back with no padding, as every snapshot
/// written before the 64-byte-anchor revision was.
fn write_unpadded(path: &std::path::Path, sections: &[RawSection]) {
    use leanvec::data::io::{bin, crc32};
    let mut out = Vec::new();
    out.extend_from_slice(&persist::MAGIC);
    bin::put_u32(&mut out, persist::FORMAT_VERSION);
    bin::put_u32(&mut out, sections.len() as u32);
    let mut offset = (16 + sections.len() * 28) as u64;
    for s in sections {
        out.extend_from_slice(&s.tag);
        bin::put_u64(&mut out, offset);
        bin::put_u64(&mut out, s.bytes.len() as u64);
        bin::put_u32(&mut out, crc32(&s.bytes));
        offset += s.bytes.len() as u64;
    }
    for s in sections {
        out.extend_from_slice(&s.bytes);
    }
    std::fs::write(path, &out).unwrap();
}

#[test]
fn aligned_snapshot_round_trips_through_owned_and_mmap_paths() {
    let built = build(
        Compression::Lvq4x8,
        Compression::F16,
        Similarity::L2,
        ProjectionKind::Id,
        19,
    );
    let path = tmp("aligned.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    // the owned reader parses the aligned layout unchanged
    let (owned, _) = LeanVecIndex::load(&path).unwrap();
    assert!(!owned.is_mapped());
    assert_search_identical(&built, &owned, 10, 6000);
    // and the mapped reader borrows it in place
    let (mapped, _) = LeanVecIndex::load_mmap(&path).unwrap();
    assert!(mapped.is_mapped());
    assert!(mapped.mapped_bytes() > 0);
    assert_search_identical(&built, &mapped, 10, 6000);
    std::fs::remove_file(&path).ok();
}

#[test]
fn pre_alignment_snapshot_loads_via_both_paths() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        20,
    );
    let path = tmp("prealign.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let sections = persist::read_sections(&path).unwrap();
    // rewrite with the legacy back-to-back layout
    write_unpadded(&path, &sections);
    let (owned, _) = LeanVecIndex::load(&path).expect("legacy layout loads");
    assert_search_identical(&built, &owned, 10, 7000);
    // load_mmap accepts it too: misaligned arrays silently decode to
    // owned memory (with a stderr note), results stay bit-identical
    let (mapped, _) = LeanVecIndex::load_mmap(&path).expect("legacy layout maps");
    assert_search_identical(&built, &mapped, 10, 7000);
    std::fs::remove_file(&path).ok();
}

#[test]
fn evict_mapped_is_safe_and_results_survive_eviction() {
    let built = build(
        Compression::Lvq8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        21,
    );
    let path = tmp("evict.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let (mapped, _) = LeanVecIndex::load_mmap(&path).unwrap();
    let mut ctx = SearchCtx::new(mapped.len());
    let q = rows(1, 16, 22).pop().unwrap();
    let query = Query::new(&q).k(10).window(30);
    let before = mapped.search(&mut ctx, &query);
    // drop every resident page; the next search refaults from disk and
    // must produce the same bits
    mapped.evict_mapped();
    let after = mapped.search(&mut ctx, &query);
    assert_eq!(before.ids, after.ids);
    let bits = |s: &[f32]| s.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&before.scores), bits(&after.scores));
    // owned indexes: a no-op, not a crash
    built.evict_mapped();
    assert_eq!(built.mapped_bytes(), 0);
    std::fs::remove_file(&path).ok();
}

#[test]
fn search_batch_identical_after_load() {
    let built = build(
        Compression::Lvq4x8,
        Compression::F16,
        Similarity::InnerProduct,
        ProjectionKind::Id,
        15,
    );
    let path = tmp("batch.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let (loaded, _) = LeanVecIndex::load(&path).unwrap();
    let queries = rows(32, 16, 16);
    let reqs: Vec<Query> = queries.iter().map(|q| Query::new(q).k(5).window(30)).collect();
    for threads in [1usize, 4] {
        let a = built.search_batch(&reqs, threads);
        let b = loaded.search_batch(&reqs, threads);
        assert_eq!(a, b, "threads {threads}");
    }
    std::fs::remove_file(&path).ok();
}
