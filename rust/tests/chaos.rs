//! Chaos battery: the request-lifecycle invariants under injected
//! faults (`--features failpoints`; see docs/ROBUSTNESS.md for the
//! failpoint catalog).
//!
//! The invariants every test here defends:
//!
//! 1. **Every admitted query resolves exactly once** — as a result, a
//!    typed error, a partial, or a degraded answer — never zero times
//!    (a hang) and never twice.
//! 2. **A misbehaving shard degrades the query, it does not fail it**
//!    (and never takes the engine down).
//! 3. **A hot-swap drops zero in-flight queries**, and a failed swap
//!    leaves the old index serving.
//!
//! The failpoint registry is process-global, so every test serializes
//! on [`failpoints::test_guard`] (which clears all armed points on
//! acquire) and clears its own points before asserting recovery.

use leanvec::config::{GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{Engine, EngineConfig, EngineError, QuerySpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::LeanVecIndex;
use leanvec::index::persist::SnapshotMeta;
use leanvec::shard::{Collection, CollectionRegistry, ShardSpec, ShardedIndex, DEFAULT_COLLECTION};
use leanvec::util::failpoints::{self, Action, Failpoint};
use leanvec::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 16;

fn rows(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..DIM).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

fn configure(b: IndexBuilder) -> IndexBuilder {
    let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
    gp.max_degree = 12;
    gp.build_window = 30;
    b.projection(ProjectionKind::Id).target_dim(8).graph_params(gp)
}

fn build_single(n: usize, seed: u64) -> Arc<LeanVecIndex> {
    Arc::new(configure(IndexBuilder::new()).build(&rows(n, seed), None, Similarity::InnerProduct))
}

fn sharded_engine(n: usize, shards: usize, workers: usize) -> Engine {
    let sharded = ShardedIndex::build(
        &rows(n, 11),
        None,
        Similarity::InnerProduct,
        ShardSpec::new(shards),
        1,
        configure,
    );
    let mut registry = CollectionRegistry::new();
    registry.register(Collection::new(DEFAULT_COLLECTION, sharded));
    Engine::start_collections(
        registry,
        EngineConfig {
            workers,
            ..EngineConfig::default()
        },
    )
}

#[test]
fn slow_shard_trips_the_deadline_and_partials_resolve() {
    let _g = failpoints::test_guard();
    let engine = sharded_engine(240, 2, 2);
    let q = vec![0.5f32; DIM];

    // shard 1 stalls well past the request budget: the deadline must
    // fire and resolve the query as a typed error, not a hang
    failpoints::set("slow_shard", Failpoint::new(Action::Sleep(80)).on_shard(1));
    let t0 = Instant::now();
    engine
        .submit_spec(q.clone(), QuerySpec::top_k(5).with_timeout_ms(15))
        .unwrap();
    let r = engine.drain(1);
    assert_eq!(r.len(), 1, "expired request still resolves");
    assert_eq!(r[0].error, Some(EngineError::DeadlineExceeded));
    assert!(!r[0].is_ok());
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline failure is prompt, not a hang"
    );

    // the same stall under allow_partial yields a usable answer
    engine
        .submit_spec(
            q.clone(),
            QuerySpec::top_k(5).with_timeout_ms(15).with_allow_partial(),
        )
        .unwrap();
    let p = engine.drain(1);
    assert_eq!(p.len(), 1);
    assert!(p[0].is_ok(), "{:?}", p[0].error);
    assert!(p[0].partial, "deadline tripped mid-search");

    // disarmed, the engine serves normally again
    failpoints::clear_all();
    engine.submit(q, 5).unwrap();
    let ok = engine.drain(1);
    assert!(ok[0].is_ok() && !ok[0].partial && !ok[0].degraded);
    let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
    assert_eq!(adm.inflight.load(Ordering::Acquire), 0, "no slot leaked");
    engine.shutdown();
}

#[test]
fn panicking_shard_degrades_queries_instead_of_failing_them() {
    let _g = failpoints::test_guard();
    let engine = sharded_engine(360, 3, 2);
    let q = vec![0.5f32; DIM];

    failpoints::set("panic_shard", Failpoint::new(Action::Panic).on_shard(1));
    for _ in 0..8 {
        engine.submit(q.clone(), 5).unwrap();
    }
    let responses = engine.drain(8);
    assert_eq!(responses.len(), 8, "every query resolved despite panics");
    for r in &responses {
        assert!(r.is_ok(), "shard panic degrades, never fails: {:?}", r.error);
        assert!(r.degraded, "failed shard is visible on the response");
        assert!(r.shards_failed >= 1);
        assert!(!r.ids.is_empty(), "surviving shards still answer");
    }

    // disarmed, service is whole again on the same engine
    failpoints::clear_all();
    engine.submit(q, 5).unwrap();
    let healed = engine.drain(1);
    assert!(healed[0].is_ok() && !healed[0].degraded);
    assert_eq!(healed[0].shards_failed, 0);
    engine.shutdown();
}

#[test]
fn injected_load_error_fails_the_swap_and_keeps_the_old_index() {
    let _g = failpoints::test_guard();
    let index_a = build_single(150, 3);
    let index_b = build_single(150, 77);
    let path = std::env::temp_dir().join(format!(
        "leanvec-chaos-swap-{}.leanvec",
        std::process::id()
    ));
    index_b.save(&path, &SnapshotMeta::default()).unwrap();

    let engine = Engine::start(Arc::clone(&index_a), EngineConfig::default());
    let q = vec![0.5f32; DIM];

    failpoints::set("io_error_on_load", Failpoint::new(Action::Error));
    match engine.swap_collection(DEFAULT_COLLECTION, &path) {
        Err(EngineError::SwapFailed { collection, reason }) => {
            assert_eq!(collection, DEFAULT_COLLECTION);
            assert!(reason.contains("injected"), "{reason}");
        }
        other => panic!("expected SwapFailed, got {other:?}"),
    }
    // the failed swap left the old index serving
    engine.submit(q.clone(), 5).unwrap();
    assert!(engine.drain(1)[0].is_ok());

    // disarmed, the same swap succeeds and the new data serves
    failpoints::clear_all();
    let report = engine.swap_collection(DEFAULT_COLLECTION, &path).unwrap();
    assert!(report.drained);
    engine.submit(q, 5).unwrap();
    assert!(engine.drain(1)[0].is_ok());
    engine.shutdown();
    std::fs::remove_file(&path).ok();
}

#[test]
fn hot_swap_under_load_drops_no_queries() {
    let _g = failpoints::test_guard();
    let index_a = build_single(200, 3);
    let index_b = build_single(200, 77);
    let pid = std::process::id();
    let path_a = std::env::temp_dir().join(format!("leanvec-chaos-soak-a-{pid}.leanvec"));
    let path_b = std::env::temp_dir().join(format!("leanvec-chaos-soak-b-{pid}.leanvec"));
    index_a.save(&path_a, &SnapshotMeta::default()).unwrap();
    index_b.save(&path_b, &SnapshotMeta::default()).unwrap();

    let engine = Engine::start(
        index_a,
        EngineConfig {
            workers: 2,
            ..EngineConfig::default()
        },
    );
    let submitted = AtomicUsize::new(0);
    let mut swaps = 0usize;
    std::thread::scope(|scope| {
        for t in 0..2u64 {
            let engine = &engine;
            let submitted = &submitted;
            scope.spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..150 {
                    let q: Vec<f32> = (0..DIM).map(|_| rng.gaussian_f32()).collect();
                    engine.submit(q, 5).unwrap();
                    submitted.fetch_add(1, Ordering::Relaxed);
                    if submitted.load(Ordering::Relaxed) % 16 == 0 {
                        std::thread::yield_now();
                    }
                }
            });
        }
        // swap back and forth while the submitters hammer the engine
        for i in 0..6 {
            let next = if i % 2 == 0 { &path_b } else { &path_a };
            let report = engine
                .swap_collection(DEFAULT_COLLECTION, next)
                .unwrap_or_else(|e| panic!("swap {i} failed: {e}"));
            assert_eq!(report.shards, 1);
            swaps += 1;
            std::thread::sleep(Duration::from_millis(2));
        }
    });
    assert_eq!(swaps, 6);

    let n = submitted.load(Ordering::Relaxed);
    let responses = engine.drain(n);
    assert_eq!(responses.len(), n, "hot-swap dropped queries: {} of {n}", responses.len());
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), n, "a query resolved more than once");
    for r in &responses {
        assert!(r.is_ok(), "swap must not fail queries: {:?}", r.error);
        assert_eq!(r.ids.len(), 5, "every answer is complete");
    }
    let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
    assert_eq!(adm.inflight.load(Ordering::Acquire), 0);
    engine.shutdown();
    std::fs::remove_file(&path_a).ok();
    std::fs::remove_file(&path_b).ok();
}

#[test]
fn every_query_resolves_exactly_once_under_a_fault_mix() {
    let _g = failpoints::test_guard();
    let engine = sharded_engine(240, 2, 2);

    // a stalling shard AND an intermittently panicking shard at once;
    // the panic budget runs dry mid-storm so late queries see a
    // healthy index again
    failpoints::set("slow_shard", Failpoint::new(Action::Sleep(3)).on_shard(0));
    failpoints::set(
        "panic_shard",
        Failpoint::new(Action::Panic).on_shard(1).times(20),
    );

    let mut rng = Rng::new(5);
    let total = 60usize;
    for i in 0..total {
        let q: Vec<f32> = (0..DIM).map(|_| rng.gaussian_f32()).collect();
        let spec = match i % 4 {
            0 => QuerySpec::top_k(5),
            1 => QuerySpec::top_k(5).with_timeout_ms(10),
            2 => QuerySpec::top_k(5).with_timeout_ms(0),
            _ => QuerySpec::top_k(5).with_timeout_ms(0).with_allow_partial(),
        };
        engine.submit_spec(q, spec).unwrap();
    }
    let t0 = Instant::now();
    let responses = engine.drain(total);
    assert_eq!(
        responses.len(),
        total,
        "every submitted query resolves exactly once under faults"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "fault mix must not wedge the drain"
    );
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), total, "no duplicate resolutions");
    // the mix produced each outcome class at least once
    assert!(responses.iter().any(|r| r.is_ok()), "some queries succeed");
    assert!(
        responses
            .iter()
            .any(|r| r.error == Some(EngineError::DeadlineExceeded)),
        "0 ms deadlines surface as typed errors"
    );
    assert!(
        responses.iter().any(|r| r.is_ok() && r.partial),
        "allow_partial deadlines surface as partials"
    );
    assert!(
        responses.iter().any(|r| r.degraded),
        "the panicking shard surfaced as degradation"
    );
    let adm = engine.collection(DEFAULT_COLLECTION).unwrap().admission();
    assert_eq!(adm.inflight.load(Ordering::Acquire), 0, "no slot leaked");
    failpoints::clear_all();
    engine.shutdown();
}
