//! `VectorIndex` conformance suite: every index kind (LeanVec, flat,
//! IVF-PQ — plus the `SearchIndex` harness wrapper) must honor the
//! `Query` contract identically: scores descend, k is respected,
//! filters exclude exactly the filtered ids (with correct
//! `QueryStats.filtered` accounting), split-buffer rerank windows
//! work, batch equals sequential, and per-request parameter overrides
//! flow through the serving `Engine`.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{Engine, EngineConfig, QuerySpec};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::{build_hnsw_baseline, IndexBuilder, SearchIndex};
use leanvec::index::ivfpq::{IvfPqIndex, IvfPqParams};
use leanvec::index::leanvec_index::{LeanVecIndex, SearchParams};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::index::FlatIndex;
use leanvec::util::rng::Rng;
use std::sync::Arc;

const N: usize = 600;
const DIM: usize = 16;
const K: usize = 10;

fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> = (0..6)
        .map(|_| (0..d).map(|_| rng.gaussian_f32() * 3.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            centers[i % 6]
                .iter()
                .map(|&x| x + rng.gaussian_f32() * 0.4)
                .collect()
        })
        .collect()
}

fn queries(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

fn build_leanvec(rows: &[Vec<f32>], sim: Similarity) -> LeanVecIndex {
    let mut gp = GraphParams::for_similarity(sim);
    gp.max_degree = 16;
    gp.build_window = 40;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(8)
        .graph_params(gp)
        .build(rows, None, sim)
}

fn build_ivfpq(rows: &[Vec<f32>], sim: Similarity) -> IvfPqIndex {
    IvfPqIndex::build(
        rows,
        IvfPqParams {
            nlist: 16,
            m: 4,
            ksub: 64,
            kmeans_iters: 6,
        },
        sim,
        5,
    )
}

/// All index kinds behind the trait, boxed into the harness wrapper so
/// one loop covers them.
fn all_kinds(rows: &[Vec<f32>], sim: Similarity) -> Vec<SearchIndex> {
    vec![
        SearchIndex::LeanVec(build_leanvec(rows, sim)),
        SearchIndex::Flat(FlatIndex::new(rows, sim)),
        SearchIndex::IvfPq(build_ivfpq(rows, sim), 16),
        build_hnsw_baseline(rows, sim, Compression::F16, 7),
    ]
}

#[test]
fn scores_descend_and_k_respected_for_every_kind() {
    let rs = rows(N, DIM, 1);
    let qs = queries(8, DIM, 2);
    for ix in all_kinds(&rs, Similarity::InnerProduct) {
        let mut ctx = SearchCtx::new(ix.len());
        for q in &qs {
            for k in [1usize, 5, K] {
                let r = ix.search(&mut ctx, &Query::new(q).k(k).window(40));
                assert_eq!(r.ids.len(), k, "{}: k not respected", ix.name());
                assert_eq!(r.ids.len(), r.scores.len(), "{}", ix.name());
                for w in r.scores.windows(2) {
                    assert!(w[0] >= w[1], "{}: scores ascend {:?}", ix.name(), r.scores);
                }
                let set: std::collections::HashSet<_> = r.ids.iter().collect();
                assert_eq!(set.len(), r.ids.len(), "{}: duplicate ids", ix.name());
                assert!(r.stats.primary_scored > 0, "{}", ix.name());
                assert!(r.stats.bytes_touched > 0, "{}", ix.name());
            }
        }
        // metadata surface
        assert_eq!(ix.len(), N);
        assert_eq!(ix.dim(), DIM);
        assert_eq!(ix.sim(), Similarity::InnerProduct);
    }
}

#[test]
fn filter_excludes_exactly_the_filtered_ids() {
    let rs = rows(N, DIM, 3);
    let qs = queries(6, DIM, 4);
    let allow = |id: u32| id % 3 == 0; // keep one id in three
    for ix in all_kinds(&rs, Similarity::L2) {
        let mut ctx = SearchCtx::new(ix.len());
        for q in &qs {
            let r = ix.search(&mut ctx, &Query::new(q).k(K).window(60).filter(&allow));
            assert!(
                r.ids.iter().all(|&id| allow(id)),
                "{}: filtered id returned: {:?}",
                ix.name(),
                r.ids
            );
            assert!(!r.ids.is_empty(), "{}: filter starved results", ix.name());
            assert!(
                r.stats.filtered > 0,
                "{}: filtered counter not accounted",
                ix.name()
            );
            // the unfiltered search must encounter no filtered nodes
            let plain = ix.search(&mut ctx, &Query::new(q).k(K).window(60));
            assert_eq!(plain.stats.filtered, 0, "{}", ix.name());
        }
    }
}

#[test]
fn flat_filtered_counts_are_exact() {
    // the flat oracle scans everything, so its accounting is exact:
    // filtered + scored == n
    let rs = rows(300, DIM, 5);
    let flat = FlatIndex::new(&rs, Similarity::InnerProduct);
    let q = &queries(1, DIM, 6)[0];
    let allow = |id: u32| id < 100;
    let r = flat.search_one(&Query::new(q).k(K).filter(&allow));
    assert_eq!(r.stats.filtered, 200);
    assert_eq!(r.stats.primary_scored, 100);
    assert!(r.ids.iter().all(|&id| id < 100));
}

#[test]
fn filtered_recall_vs_filtered_flat_oracle() {
    let rs = rows(800, DIM, 7);
    let index = build_leanvec(&rs, Similarity::InnerProduct);
    let flat = FlatIndex::new(&rs, Similarity::InnerProduct);
    let qs = queries(30, DIM, 8);
    let allow = |id: u32| id % 2 == 0; // 50% selectivity
    let mut ctx = SearchCtx::new(rs.len());
    let mut hits = 0usize;
    for q in &qs {
        let truth = flat.search_one(&Query::new(q).k(K).filter(&allow)).ids;
        let got = index
            .search(&mut ctx, &Query::new(q).k(K).window(100).filter(&allow))
            .ids;
        assert!(got.iter().all(|&id| allow(id)));
        hits += truth.iter().filter(|t| got.contains(t)).count();
    }
    let recall = hits as f64 / (K * qs.len()) as f64;
    assert!(recall >= 0.75, "filtered recall vs filtered oracle: {recall}");
}

#[test]
fn split_buffer_rerank_window_may_exceed_window() {
    let rs = rows(N, DIM, 9);
    let index = build_leanvec(&rs, Similarity::InnerProduct);
    let q = &queries(1, DIM, 10)[0];
    let mut ctx = SearchCtx::new(rs.len());
    let wide = index.search(&mut ctx, &Query::new(q).k(5).window(15).rerank_window(60));
    // more candidates were retained and re-ranked than the traversal
    // window alone can hold
    assert!(wide.stats.reranked > 15, "{:?}", wide.stats);
    let narrow = index.search(&mut ctx, &Query::new(q).k(5).window(15));
    assert!(narrow.stats.reranked <= 15, "{:?}", narrow.stats);
    // identical traversal effort: the split buffer widens retention,
    // not expansion
    assert_eq!(wide.stats.hops, narrow.stats.hops);
    assert_eq!(wide.stats.primary_scored, narrow.stats.primary_scored);
}

#[test]
fn no_rerank_reports_zero_reranked() {
    let rs = rows(N, DIM, 11);
    let index = build_leanvec(&rs, Similarity::InnerProduct);
    let q = &queries(1, DIM, 12)[0];
    let r = index.search_one(&Query::new(q).k(5).window(30).no_rerank());
    assert_eq!(r.stats.reranked, 0);
    assert_eq!(r.ids.len(), 5);
    for w in r.scores.windows(2) {
        assert!(w[0] >= w[1]);
    }
}

#[test]
fn batch_matches_sequential_via_the_trait_for_every_kind() {
    let rs = rows(N, DIM, 13);
    let qs = queries(16, DIM, 14);
    for ix in all_kinds(&rs, Similarity::InnerProduct) {
        let reqs: Vec<Query> = qs.iter().map(|q| Query::new(q).k(5).window(30)).collect();
        let mut ctx = SearchCtx::new(ix.len());
        let sequential: Vec<Vec<u32>> =
            reqs.iter().map(|q| ix.search(&mut ctx, q).ids).collect();
        for threads in [1usize, 3] {
            let batched: Vec<Vec<u32>> = ix
                .search_batch(&reqs, threads)
                .into_iter()
                .map(|r| r.ids)
                .collect();
            assert_eq!(batched, sequential, "{} threads {threads}", ix.name());
        }
    }
}

#[test]
fn zero_k_returns_empty_for_every_kind() {
    let rs = rows(200, DIM, 15);
    let q = &queries(1, DIM, 16)[0];
    for ix in all_kinds(&rs, Similarity::InnerProduct) {
        let r = ix.search_one(&Query::new(q).k(0).window(20));
        assert!(r.ids.is_empty(), "{}", ix.name());
        assert!(r.scores.is_empty(), "{}", ix.name());
    }
}

// ---- per-request parameters and filters through the serving engine

fn engine_fixture() -> (Arc<LeanVecIndex>, Vec<Vec<f32>>) {
    let rs = rows(700, DIM, 17);
    let index = Arc::new(build_leanvec(&rs, Similarity::InnerProduct));
    let qs = queries(6, DIM, 18);
    (index, qs)
}

#[test]
fn engine_honors_per_request_params_over_defaults() {
    let (index, qs) = engine_fixture();
    let engine = Engine::start(
        Arc::clone(&index),
        EngineConfig {
            workers: 2,
            search: SearchParams {
                window: 4,
                rerank_window: 4,
            },
            ..EngineConfig::default()
        },
    );
    for q in &qs {
        engine
            .submit_spec(
                q.clone(),
                QuerySpec::top_k(K).with_window(80).with_rerank_window(160),
            )
            .unwrap();
    }
    let mut responses = engine.drain(qs.len());
    responses.sort_by_key(|r| r.id);
    engine.shutdown();
    for (resp, q) in responses.iter().zip(qs.iter()) {
        let direct = index.search_one(&Query::new(q).k(K).window(80).rerank_window(160));
        assert_eq!(resp.ids, direct.ids, "override ignored by worker");
        assert_eq!(resp.stats, direct.stats, "stats not echoed faithfully");
        assert!(resp.stats.reranked > 4, "engine-wide default leaked in");
    }
}

#[test]
fn engine_filtered_query_returns_only_allowed_ids_with_accounting() {
    let (index, qs) = engine_fixture();
    // allow-list: every third id
    let allow_ids: Vec<u32> = (0..index.len() as u32).filter(|id| id % 3 == 0).collect();
    let engine = Engine::start(Arc::clone(&index), EngineConfig {
        workers: 2,
        ..EngineConfig::default()
    });
    for q in &qs {
        engine
            .submit_spec(
                q.clone(),
                QuerySpec::top_k(K)
                    .with_window(80)
                    .with_allow_list(allow_ids.clone()),
            )
            .unwrap();
    }
    let mut responses = engine.drain(qs.len());
    responses.sort_by_key(|r| r.id);
    engine.shutdown();
    let pred = |id: u32| id % 3 == 0;
    for (resp, q) in responses.iter().zip(qs.iter()) {
        assert!(
            resp.ids.iter().all(|&id| pred(id)),
            "engine returned a filtered-out id: {:?}",
            resp.ids
        );
        assert!(!resp.ids.is_empty());
        // QueryStats.filtered must match a direct filtered search
        let direct = index.search_one(&Query::new(q).k(K).window(80).filter(&pred));
        assert_eq!(resp.ids, direct.ids);
        assert_eq!(
            resp.stats.filtered, direct.stats.filtered,
            "filtered accounting diverged between engine and direct path"
        );
        assert!(resp.stats.filtered > 0);
    }
}

#[test]
fn mixed_specs_in_one_engine_batch_each_honored() {
    let (index, qs) = engine_fixture();
    let engine = Engine::start(Arc::clone(&index), EngineConfig {
        workers: 1,
        ..EngineConfig::default()
    });
    // same query, three different specs, submitted back to back (they
    // may batch together; the batcher is spec-heterogeneous by design)
    let q = qs[0].clone();
    engine.submit_spec(q.clone(), QuerySpec::top_k(3)).unwrap();
    engine
        .submit_spec(q.clone(), QuerySpec::top_k(7).with_window(100))
        .unwrap();
    engine
        .submit_spec(q.clone(), QuerySpec::top_k(5).with_allow_list(vec![]))
        .unwrap();
    let mut responses = engine.drain(3);
    responses.sort_by_key(|r| r.id);
    engine.shutdown();
    assert_eq!(responses[0].ids.len(), 3);
    assert_eq!(responses[1].ids.len(), 7);
    // an empty allow-list filters everything: no results, full accounting
    assert!(responses[2].ids.is_empty());
    assert!(responses[2].stats.filtered > 0);
}
