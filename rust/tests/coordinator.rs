//! Coordinator integration: batching behaviour, concurrency, recall
//! through the full serve path, and failure-ish edges.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig};
use leanvec::data::gt::ground_truth;
use leanvec::data::synth::{generate, QueryDist, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use std::sync::Arc;
use std::time::Duration;

fn dataset(n: usize) -> leanvec::data::synth::Dataset {
    generate(&SynthSpec {
        name: "coord".into(),
        dim: 96,
        n,
        n_learn_queries: 200,
        n_test_queries: 100,
        similarity: Similarity::InnerProduct,
        queries: QueryDist::OutOfDistribution(0.6),
        decay: 0.6,
        seed: 77,
    })
}

fn build(ds: &leanvec::data::synth::Dataset) -> Arc<leanvec::index::leanvec_index::LeanVecIndex> {
    let mut gp = GraphParams::for_similarity(ds.similarity);
    gp.max_degree = 20;
    gp.build_window = 40;
    Arc::new(
        IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(32)
            .primary(Compression::Lvq8)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity),
    )
}

#[test]
fn full_serve_path_reaches_good_recall() {
    let ds = dataset(2_000);
    let index = build(&ds);
    let truth = ground_truth(&ds.database, &ds.test_queries, 10, ds.similarity);
    let cfg = EngineConfig {
        workers: 2,
        search: SearchParams {
            window: 80,
            rerank_window: 80,
        },
        ..Default::default()
    };
    let (responses, report) =
        Engine::run_workload(index, cfg, &ds.test_queries, 10, Some(&truth));
    assert_eq!(responses.len(), ds.test_queries.len());
    assert!(report.recall_at_k >= 0.85, "recall {}", report.recall_at_k);
    assert!(report.metrics.qps > 0.0);
    assert!(report.metrics.latency_p99_ms >= report.metrics.latency_p50_ms);
}

#[test]
fn batches_form_under_load() {
    let ds = dataset(1_000);
    let index = build(&ds);
    let cfg = EngineConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: Duration::from_millis(20),
        },
        ..Default::default()
    };
    // submit a burst before workers can drain -> batches > 1
    let engine = Engine::start(index, cfg);
    for q in ds.test_queries.iter().take(64) {
        engine.submit(q.clone(), 5).unwrap();
    }
    let responses = engine.drain(64);
    engine.shutdown();
    let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
    assert!(max_batch > 1, "no batching under burst load");
    assert!(max_batch <= 32, "batch exceeded policy: {max_batch}");
}

#[test]
fn single_request_not_starved_by_batcher() {
    let ds = dataset(800);
    let index = build(&ds);
    let cfg = EngineConfig {
        workers: 1,
        batch: BatchPolicy {
            max_batch: 1024,
            max_wait: Duration::from_millis(5),
        },
        ..Default::default()
    };
    let engine = Engine::start(index, cfg);
    let t0 = std::time::Instant::now();
    engine.submit(ds.test_queries[0].clone(), 5).unwrap();
    let r = engine.drain(1);
    engine.shutdown();
    assert_eq!(r.len(), 1);
    // must be released by max_wait, not wait for a full batch
    assert!(t0.elapsed() < Duration::from_secs(1));
}

#[test]
fn many_workers_agree_with_single_worker() {
    let ds = dataset(1_500);
    let index = build(&ds);
    let run = |workers: usize| {
        let cfg = EngineConfig {
            workers,
            ..Default::default()
        };
        let (mut responses, _) = Engine::run_workload(
            Arc::clone(&index),
            cfg,
            &ds.test_queries[..32].to_vec(),
            5,
            None,
        );
        responses.sort_by_key(|r| r.id);
        responses.into_iter().map(|r| r.ids).collect::<Vec<_>>()
    };
    assert_eq!(run(1), run(3), "results must not depend on worker count");
}

#[test]
fn zero_k_requests_return_empty() {
    let ds = dataset(500);
    let index = build(&ds);
    let engine = Engine::start(index, EngineConfig::default());
    engine.submit(ds.test_queries[0].clone(), 0).unwrap();
    let r = engine.drain(1);
    engine.shutdown();
    assert!(r[0].ids.is_empty());
}

#[test]
fn throughput_improves_with_batching_amortization() {
    // not asserting a ratio (1-core CI) — just that the batched engine
    // completes a large workload without loss and reports sane numbers
    let ds = dataset(1_000);
    let index = build(&ds);
    let queries: Vec<Vec<f32>> = (0..500)
        .map(|i| ds.test_queries[i % ds.test_queries.len()].clone())
        .collect();
    let cfg = EngineConfig {
        workers: 2,
        batch: BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
        },
        ..Default::default()
    };
    let (responses, report) = Engine::run_workload(index, cfg, &queries, 10, None);
    assert_eq!(responses.len(), 500);
    assert!(report.metrics.mean_batch >= 1.0);
    assert!(report.metrics.qps > 10.0, "{}", report.metrics.qps);
}
