//! Score/decode consistency across every store type, scalar-vs-SIMD
//! kernel parity, blocked-vs-per-id scoring identity, plus
//! parallel-vs-serial build parity.
//!
//! The contract under test: for every compression and similarity, the
//! re-ranking score a store reports for a vector must agree with the
//! similarity computed against that store's own `decode` output —
//! `score_rerank(pq, id) ≈ sim(q, decode(id))` — including the 4-bit
//! nibble tail at odd dimensions. (For two-level LVQ4x8 the traversal
//! `score` reads only the first level by design; `score_rerank` is the
//! decode-consistent one.)

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::{make_store, make_store_threads};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::linalg::matrix::dot;
use leanvec::prop_assert;
use leanvec::util::prop::{check, Config, Gen};

const ALL_COMPRESSIONS: [Compression; 5] = [
    Compression::F32,
    Compression::F16,
    Compression::Lvq8,
    Compression::Lvq4,
    Compression::Lvq4x8,
];

/// The similarity a store's score should express, computed directly
/// against decoded vectors: IP -> `<q, x>`; L2 -> `2<q,x> - ||x||^2`.
fn expected_score(q: &[f32], dec: &[f32], sim: Similarity) -> f32 {
    match sim {
        Similarity::InnerProduct | Similarity::Cosine => dot(q, dec),
        Similarity::L2 => 2.0 * dot(q, dec) - dot(dec, dec),
    }
}

fn rows_from(g: &mut Gen, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.vec_gaussian(d)).collect()
}

#[test]
fn prop_score_rerank_matches_decode_all_stores_both_sims() {
    check("score-decode-consistency", Config::default(), |g| {
        let n = g.usize_in(2, 30);
        // force odd dimensions half the time to exercise the 4-bit
        // nibble tail; keep a spread of sizes either way
        let mut d = g.usize_in(3, 97);
        if g.usize_in(0, 1) == 0 {
            d |= 1;
        }
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        for compression in ALL_COMPRESSIONS {
            let store = make_store(&rows, compression);
            for sim in [Similarity::InnerProduct, Similarity::L2] {
                let pq = store.prepare(&q, sim);
                for id in 0..n as u32 {
                    let got = store.score_rerank(&pq, id);
                    let dec = store.decode(id);
                    prop_assert!(
                        dec.len() == d,
                        "{compression:?} decode length {} != {d}",
                        dec.len()
                    );
                    let want = expected_score(&q, &dec, sim);
                    let tol = 1e-2 * (1.0 + want.abs());
                    prop_assert!(
                        (got - want).abs() <= tol,
                        "{compression:?}/{sim:?} id {id}: score_rerank {got} vs decode-sim {want}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_traversal_score_matches_first_level_decode_single_level_stores() {
    // for single-level stores the traversal score itself must already be
    // decode-consistent (score_rerank is just score)
    check("traversal-score-decode", Config::default(), |g| {
        let n = g.usize_in(2, 20);
        let d = g.usize_in(3, 65) | 1; // always odd: nibble-tail stress
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        for compression in [Compression::Lvq4, Compression::Lvq8, Compression::F16] {
            let store = make_store(&rows, compression);
            let pq = store.prepare(&q, Similarity::InnerProduct);
            for id in 0..n as u32 {
                let got = store.score(&pq, id);
                let want = dot(&q, &store.decode(id));
                prop_assert!(
                    (got - want).abs() <= 1e-2 * (1.0 + want.abs()),
                    "{compression:?} id {id}: {got} vs {want}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_store_encoding_is_bit_identical_for_every_compression() {
    let mut g_rng = leanvec::util::rng::Rng::new(71);
    let rows: Vec<Vec<f32>> = (0..600)
        .map(|_| (0..33).map(|_| g_rng.gaussian_f32()).collect())
        .collect();
    for compression in ALL_COMPRESSIONS {
        let serial = make_store(&rows, compression);
        let parallel = make_store_threads(&rows, compression, 4);
        assert_eq!(serial.len(), parallel.len());
        assert_eq!(
            serial.bytes_per_vector(),
            parallel.bytes_per_vector(),
            "{compression:?}"
        );
        for id in (0..600u32).step_by(37) {
            assert_eq!(
                serial.decode(id),
                parallel.decode(id),
                "{compression:?} id {id}"
            );
        }
    }
}

/// Awkward shapes for the kernel layer: empty, single element, below
/// one SIMD lane (8), exactly one lane, one-past, odd nibble tails,
/// and a couple of realistic dims.
const AWKWARD_DIMS: [usize; 10] = [0, 1, 3, 7, 8, 9, 16, 17, 33, 96];

#[test]
fn kernel_parity_scalar_vs_dispatched_awkward_dims() {
    // On an AVX2 host this pins the dispatched kernels against the
    // scalar references at 1e-4 relative tolerance; with
    // LEANVEC_FORCE_SCALAR=1 (the second CI run) both sides are the
    // same function and the comparison is exact.
    use leanvec::simd;
    let mut rng = leanvec::util::rng::Rng::new(0x51AD);
    for &n in &AWKWARD_DIMS {
        for trial in 0..8u64 {
            let q: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let a: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let h: Vec<u16> = leanvec::util::f16::encode_slice(&a);
            let c8: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let c4: Vec<u8> = (0..n.div_ceil(2)).map(|_| rng.below(256) as u8).collect();
            let close = |got: f32, want: f32, what: &str| {
                assert!(
                    (got - want).abs() <= 1e-4 * (1.0 + want.abs()),
                    "{what} n={n} trial={trial}: dispatched {got} vs scalar {want}"
                );
            };
            close(simd::dot_f32(&q, &a), simd::scalar::dot_f32(&q, &a), "dot_f32");
            close(simd::dot_f16(&h, &q), simd::scalar::dot_f16(&h, &q), "dot_f16");
            close(simd::dot_u8(&c8, &q), simd::scalar::dot_u8(&c8, &q), "dot_u8");
            close(simd::dot_u4(&c4, &q), simd::scalar::dot_u4(&c4, &q), "dot_u4");
            let (g4, g8) = simd::dot_u4_u8(&c4, &c8, &q);
            let (w4, w8) = simd::scalar::dot_u4_u8(&c4, &c8, &q);
            close(g4, w4, "dot_u4_u8.0");
            close(g8, w8, "dot_u4_u8.1");
        }
    }
}

#[test]
fn score_block_bitwise_matches_score_every_store_sim_dim() {
    // The blocked entry points must reproduce the per-id scores *bit
    // for bit* (same kernel, same data) for every store kind, both
    // similarities, and every awkward dimension — including dim where
    // a whole SIMD lane never fills.
    check("score-block-identity", Config::default(), |g| {
        let d = AWKWARD_DIMS[g.usize_in(1, AWKWARD_DIMS.len() - 1)]; // skip 0: stores need a dim
        let n = g.usize_in(1, 40);
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        let ids: Vec<u32> = (0..n as u32).collect();
        let (mut block, mut rerank_block) = (Vec::new(), Vec::new());
        for compression in ALL_COMPRESSIONS {
            let store = make_store(&rows, compression);
            for sim in [Similarity::InnerProduct, Similarity::L2] {
                let pq = store.prepare(&q, sim);
                store.score_block(&pq, &ids, &mut block);
                store.score_rerank_block(&pq, &ids, &mut rerank_block);
                prop_assert!(block.len() == n && rerank_block.len() == n, "lengths");
                for &id in &ids {
                    let i = id as usize;
                    prop_assert!(
                        block[i].to_bits() == store.score(&pq, id).to_bits(),
                        "{compression:?}/{sim:?} d={d} id={id}: score_block {} vs score {}",
                        block[i],
                        store.score(&pq, id)
                    );
                    prop_assert!(
                        rerank_block[i].to_bits() == store.score_rerank(&pq, id).to_bits(),
                        "{compression:?}/{sim:?} d={d} id={id}: rerank_block {} vs {}",
                        rerank_block[i],
                        store.score_rerank(&pq, id)
                    );
                }
            }
        }
        Ok(())
    });
}

/// f64 reference score from a store's own decode (the high-precision
/// twin of what `score` computes in f32).
fn ref_score_f64(q: &[f32], dec: &[f32], sim: Similarity) -> f64 {
    let ip: f64 = q.iter().zip(dec.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
    match sim {
        Similarity::InnerProduct | Similarity::Cosine => ip,
        Similarity::L2 => {
            let nsq: f64 = dec.iter().map(|&x| x as f64 * x as f64).sum();
            2.0 * ip - nsq
        }
    }
}

#[test]
fn topk_ranking_matches_f64_reference_every_store() {
    // Gaussian data, realistic dim: the top-10 ranking produced by the
    // dispatched kernels must match the f64 decode-based reference
    // ranking, except where two reference scores genuinely tie within
    // tolerance (summation-order noise may legally swap those).
    let mut rng = leanvec::util::rng::Rng::new(0xBEEF);
    let n = 300usize;
    let d = 96usize;
    let rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect();
    let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
    let ids: Vec<u32> = (0..n as u32).collect();
    let k = 10usize;
    for compression in ALL_COMPRESSIONS {
        let store = make_store(&rows, compression);
        for sim in [Similarity::InnerProduct, Similarity::L2] {
            let pq = store.prepare(&q, sim);
            let mut scores = Vec::new();
            store.score_block(&pq, &ids, &mut scores);
            let mut got: Vec<u32> = ids.clone();
            got.sort_by(|&a, &b| scores[b as usize].total_cmp(&scores[a as usize]));
            got.truncate(k);
            // reference ranking of *traversal* scores: first level only
            // for LVQ4x8, so decode the matching representation
            let ref_store = match compression {
                Compression::Lvq4x8 => make_store(&rows, Compression::Lvq4),
                _ => make_store(&rows, compression),
            };
            let mut refs: Vec<f64> = Vec::with_capacity(n);
            for id in 0..n as u32 {
                refs.push(ref_score_f64(&q, &ref_store.decode(id), sim));
            }
            let mut want: Vec<u32> = ids.clone();
            want.sort_by(|&a, &b| refs[b as usize].total_cmp(&refs[a as usize]));
            want.truncate(k);
            for (pos, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                if g != w {
                    let diff = (refs[g as usize] - refs[w as usize]).abs();
                    let scale = 1.0 + refs[w as usize].abs();
                    assert!(
                        diff <= 1e-3 * scale,
                        "{compression:?}/{sim:?} rank {pos}: id {g} vs {w} \
                         (ref scores {} vs {})",
                        refs[g as usize],
                        refs[w as usize]
                    );
                }
            }
        }
    }
}

#[test]
fn force_scalar_override_pins_the_scalar_kernels() {
    // self-describing dispatch: when the env override is present the
    // dispatcher must report (and use) the scalar set — the CI runs the
    // whole suite a second time under LEANVEC_FORCE_SCALAR=1 to drive
    // every test above through this path
    let forced = leanvec::simd::force_scalar_requested();
    let features = leanvec::simd::active_features();
    if forced {
        assert!(
            features.starts_with("scalar"),
            "forced scalar but dispatcher picked {features}"
        );
        // spot-check: dispatched == scalar exactly
        let q: Vec<f32> = (0..33).map(|i| (i as f32).sin()).collect();
        let c: Vec<u8> = (0..33).map(|i| (i * 7 % 251) as u8).collect();
        assert_eq!(
            leanvec::simd::dot_u8(&c, &q).to_bits(),
            leanvec::simd::scalar::dot_u8(&c, &q).to_bits()
        );
    }
    assert!(!features.is_empty());
}

fn build_index(
    rows: &[Vec<f32>],
    learn: &[Vec<f32>],
    threads: usize,
) -> leanvec::index::leanvec_index::LeanVecIndex {
    let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
    gp.max_degree = 24;
    gp.build_window = 48;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(24)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .graph_params(gp)
        .seed(99)
        .build_threads(threads)
        .build(rows, Some(learn), Similarity::InnerProduct)
}

#[test]
fn parallel_and_serial_builds_reach_the_same_recall() {
    let ds = leanvec::data::synth::generate(&leanvec::data::synth::SynthSpec {
        name: "parity".into(),
        dim: 64,
        n: 1_500,
        n_learn_queries: 200,
        n_test_queries: 100,
        similarity: Similarity::InnerProduct,
        queries: leanvec::data::synth::QueryDist::InDistribution,
        decay: 0.6,
        seed: 31,
    });
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let serial = build_index(&ds.database, &ds.learn_queries, 1);
    let parallel = build_index(&ds.database, &ds.learn_queries, 4);

    let reqs: Vec<Query> = ds
        .test_queries
        .iter()
        .map(|q| Query::new(q).k(k).window(80))
        .collect();
    let recall = |ix: &leanvec::index::leanvec_index::LeanVecIndex| {
        let got: Vec<Vec<u32>> = ix
            .search_batch(&reqs, 2)
            .into_iter()
            .map(|r| r.ids)
            .collect();
        recall_at_k(&got, &truth, k)
    };
    let r_serial = recall(&serial);
    let r_parallel = recall(&parallel);
    assert!(r_serial >= 0.85, "serial recall {r_serial}");
    // acceptance: parallel recall within 1 point of serial (+ noise slack)
    assert!(
        r_parallel >= r_serial - 0.02,
        "parallel {r_parallel} vs serial {r_serial}"
    );
}

#[test]
fn parallel_build_same_codes_as_serial() {
    // quantization and projection are bit-identical across thread
    // counts; only the graph schedule differs
    let ds = leanvec::data::synth::generate(&leanvec::data::synth::SynthSpec {
        name: "codes".into(),
        dim: 48,
        n: 700,
        n_learn_queries: 100,
        n_test_queries: 50,
        similarity: Similarity::InnerProduct,
        queries: leanvec::data::synth::QueryDist::InDistribution,
        decay: 0.6,
        seed: 32,
    });
    let serial = build_index(&ds.database, &ds.learn_queries, 1);
    let parallel = build_index(&ds.database, &ds.learn_queries, 4);
    for id in (0..700u32).step_by(61) {
        assert_eq!(serial.primary.decode(id), parallel.primary.decode(id));
        assert_eq!(serial.secondary.decode(id), parallel.secondary.decode(id));
    }
}
