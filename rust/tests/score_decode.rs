//! Score/decode consistency across every store type, plus
//! parallel-vs-serial build parity.
//!
//! The contract under test: for every compression and similarity, the
//! re-ranking score a store reports for a vector must agree with the
//! similarity computed against that store's own `decode` output —
//! `score_rerank(pq, id) ≈ sim(q, decode(id))` — including the 4-bit
//! nibble tail at odd dimensions. (For two-level LVQ4x8 the traversal
//! `score` reads only the first level by design; `score_rerank` is the
//! decode-consistent one.)

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::{make_store, make_store_threads};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::linalg::matrix::dot;
use leanvec::prop_assert;
use leanvec::util::prop::{check, Config, Gen};

const ALL_COMPRESSIONS: [Compression; 5] = [
    Compression::F32,
    Compression::F16,
    Compression::Lvq8,
    Compression::Lvq4,
    Compression::Lvq4x8,
];

/// The similarity a store's score should express, computed directly
/// against decoded vectors: IP -> `<q, x>`; L2 -> `2<q,x> - ||x||^2`.
fn expected_score(q: &[f32], dec: &[f32], sim: Similarity) -> f32 {
    match sim {
        Similarity::InnerProduct | Similarity::Cosine => dot(q, dec),
        Similarity::L2 => 2.0 * dot(q, dec) - dot(dec, dec),
    }
}

fn rows_from(g: &mut Gen, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.vec_gaussian(d)).collect()
}

#[test]
fn prop_score_rerank_matches_decode_all_stores_both_sims() {
    check("score-decode-consistency", Config::default(), |g| {
        let n = g.usize_in(2, 30);
        // force odd dimensions half the time to exercise the 4-bit
        // nibble tail; keep a spread of sizes either way
        let mut d = g.usize_in(3, 97);
        if g.usize_in(0, 1) == 0 {
            d |= 1;
        }
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        for compression in ALL_COMPRESSIONS {
            let store = make_store(&rows, compression);
            for sim in [Similarity::InnerProduct, Similarity::L2] {
                let pq = store.prepare(&q, sim);
                for id in 0..n as u32 {
                    let got = store.score_rerank(&pq, id);
                    let dec = store.decode(id);
                    prop_assert!(
                        dec.len() == d,
                        "{compression:?} decode length {} != {d}",
                        dec.len()
                    );
                    let want = expected_score(&q, &dec, sim);
                    let tol = 1e-2 * (1.0 + want.abs());
                    prop_assert!(
                        (got - want).abs() <= tol,
                        "{compression:?}/{sim:?} id {id}: score_rerank {got} vs decode-sim {want}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_traversal_score_matches_first_level_decode_single_level_stores() {
    // for single-level stores the traversal score itself must already be
    // decode-consistent (score_rerank is just score)
    check("traversal-score-decode", Config::default(), |g| {
        let n = g.usize_in(2, 20);
        let d = g.usize_in(3, 65) | 1; // always odd: nibble-tail stress
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        for compression in [Compression::Lvq4, Compression::Lvq8, Compression::F16] {
            let store = make_store(&rows, compression);
            let pq = store.prepare(&q, Similarity::InnerProduct);
            for id in 0..n as u32 {
                let got = store.score(&pq, id);
                let want = dot(&q, &store.decode(id));
                prop_assert!(
                    (got - want).abs() <= 1e-2 * (1.0 + want.abs()),
                    "{compression:?} id {id}: {got} vs {want}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn threaded_store_encoding_is_bit_identical_for_every_compression() {
    let mut g_rng = leanvec::util::rng::Rng::new(71);
    let rows: Vec<Vec<f32>> = (0..600)
        .map(|_| (0..33).map(|_| g_rng.gaussian_f32()).collect())
        .collect();
    for compression in ALL_COMPRESSIONS {
        let serial = make_store(&rows, compression);
        let parallel = make_store_threads(&rows, compression, 4);
        assert_eq!(serial.len(), parallel.len());
        assert_eq!(
            serial.bytes_per_vector(),
            parallel.bytes_per_vector(),
            "{compression:?}"
        );
        for id in (0..600u32).step_by(37) {
            assert_eq!(
                serial.decode(id),
                parallel.decode(id),
                "{compression:?} id {id}"
            );
        }
    }
}

fn build_index(
    rows: &[Vec<f32>],
    learn: &[Vec<f32>],
    threads: usize,
) -> leanvec::index::leanvec_index::LeanVecIndex {
    let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
    gp.max_degree = 24;
    gp.build_window = 48;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(24)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .graph_params(gp)
        .seed(99)
        .build_threads(threads)
        .build(rows, Some(learn), Similarity::InnerProduct)
}

#[test]
fn parallel_and_serial_builds_reach_the_same_recall() {
    let ds = leanvec::data::synth::generate(&leanvec::data::synth::SynthSpec {
        name: "parity".into(),
        dim: 64,
        n: 1_500,
        n_learn_queries: 200,
        n_test_queries: 100,
        similarity: Similarity::InnerProduct,
        queries: leanvec::data::synth::QueryDist::InDistribution,
        decay: 0.6,
        seed: 31,
    });
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let serial = build_index(&ds.database, &ds.learn_queries, 1);
    let parallel = build_index(&ds.database, &ds.learn_queries, 4);

    let reqs: Vec<Query> = ds
        .test_queries
        .iter()
        .map(|q| Query::new(q).k(k).window(80))
        .collect();
    let recall = |ix: &leanvec::index::leanvec_index::LeanVecIndex| {
        let got: Vec<Vec<u32>> = ix
            .search_batch(&reqs, 2)
            .into_iter()
            .map(|r| r.ids)
            .collect();
        recall_at_k(&got, &truth, k)
    };
    let r_serial = recall(&serial);
    let r_parallel = recall(&parallel);
    assert!(r_serial >= 0.85, "serial recall {r_serial}");
    // acceptance: parallel recall within 1 point of serial (+ noise slack)
    assert!(
        r_parallel >= r_serial - 0.02,
        "parallel {r_parallel} vs serial {r_serial}"
    );
}

#[test]
fn parallel_build_same_codes_as_serial() {
    // quantization and projection are bit-identical across thread
    // counts; only the graph schedule differs
    let ds = leanvec::data::synth::generate(&leanvec::data::synth::SynthSpec {
        name: "codes".into(),
        dim: 48,
        n: 700,
        n_learn_queries: 100,
        n_test_queries: 50,
        similarity: Similarity::InnerProduct,
        queries: leanvec::data::synth::QueryDist::InDistribution,
        decay: 0.6,
        seed: 32,
    });
    let serial = build_index(&ds.database, &ds.learn_queries, 1);
    let parallel = build_index(&ds.database, &ds.learn_queries, 4);
    for id in (0..700u32).step_by(61) {
        assert_eq!(serial.primary.decode(id), parallel.primary.decode(id));
        assert_eq!(serial.secondary.decode(id), parallel.secondary.decode(id));
    }
}
