//! Live-index integration tests: concurrent read/write soak, the
//! churn-recall acceptance bar (insert 20% / delete 10% on a
//! snapshot-loaded index, recall within 2 points of a fresh rebuild),
//! and live snapshot round-trips (bit-identical search, byte-identical
//! re-save, loud rejection by frozen-only readers).

use leanvec::config::{GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{Engine, EngineConfig};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::LeanVecIndex;
use leanvec::index::persist::{SnapshotError, SnapshotMeta};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::index::FlatIndex;
use leanvec::mutate::LiveIndex;
use leanvec::util::rng::Rng;
use std::sync::Arc;

/// A few well-separated Gaussian blobs: an easy, stable recall target.
fn clustered_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let k = 5;
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.gaussian_f32() * 4.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centers[i % k];
            c.iter().map(|&x| x + rng.gaussian_f32() * 0.3).collect()
        })
        .collect()
}

fn build(rows: &[Vec<f32>], target_dim: usize) -> LeanVecIndex {
    let mut gp = GraphParams::for_similarity(Similarity::L2);
    gp.max_degree = 24;
    gp.build_window = 60;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(target_dim)
        .graph_params(gp)
        .build(rows, None, Similarity::L2)
}

/// Recall@k of `index` against the exact flat oracle over the live
/// corpus (`(ext_id, vector)` pairs), probing with perturbed corpus
/// vectors.
fn live_recall(
    index: &dyn VectorIndex,
    corpus: &[(u32, Vec<f32>)],
    k: usize,
    window: usize,
    probes: usize,
    seed: u64,
) -> f64 {
    let rows: Vec<Vec<f32>> = corpus.iter().map(|(_, v)| v.clone()).collect();
    let flat = FlatIndex::new(&rows, Similarity::L2);
    let mut rng = Rng::new(seed);
    let mut ctx = SearchCtx::new(0);
    let mut hits = 0usize;
    for _ in 0..probes {
        let q: Vec<f32> = rows[rng.below(rows.len())]
            .iter()
            .map(|&x| x + 0.05 * rng.gaussian_f32())
            .collect();
        let (pos, _) = flat.search(&q, k);
        let truth: Vec<u32> = pos.iter().map(|&p| corpus[p as usize].0).collect();
        let got = index.search(&mut ctx, &Query::new(&q).k(k).window(window));
        hits += got.ids.iter().filter(|id| truth.contains(id)).count();
    }
    hits as f64 / (probes * k) as f64
}

#[test]
fn soak_interleaved_mutations_and_searches() {
    let dim = 16;
    let rows = clustered_rows(800, dim, 1);
    let live = Arc::new(LiveIndex::from_index(build(&rows, 8)));
    // pre-delete a slice synchronously: these ids must NEVER appear in
    // any result for the rest of the test, churn or not
    for id in 0..40u32 {
        live.delete(id).unwrap();
    }
    let mut engine = Engine::start_live(
        Arc::clone(&live),
        EngineConfig {
            workers: 2,
            consolidate_threshold: 0.15,
            ..EngineConfig::default()
        },
    );
    // a direct-search stressor thread outside the engine: hammers the
    // read path while the ingest lane mutates
    let stress_live = Arc::clone(&live);
    let stressor = std::thread::spawn(move || {
        let mut rng = Rng::new(99);
        let mut ctx = SearchCtx::new(0);
        let mut seen = 0usize;
        for _ in 0..300 {
            let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 2.0).collect();
            let r = stress_live.search(&mut ctx, &Query::new(&q).k(10).window(50));
            assert!(r.ids.len() <= 10);
            for w in r.scores.windows(2) {
                assert!(w[0] >= w[1], "scores out of order under churn");
            }
            for id in &r.ids {
                assert!(*id >= 40, "pre-deleted id {id} surfaced mid-churn");
            }
            seen += r.ids.len();
        }
        seen
    });
    // churn through the ingest lane, searches interleaved
    let mut rng = Rng::new(7);
    let mut submitted = 0usize;
    for round in 0..20u32 {
        for j in 0..8u32 {
            let v: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 2.0).collect();
            engine.submit_insert(10_000 + round * 8 + j, v).unwrap();
        }
        for j in 0..4u32 {
            engine.submit_delete(40 + round * 4 + j).unwrap();
        }
        for _ in 0..10 {
            let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 2.0).collect();
            engine.submit(q, 10).unwrap();
        }
        submitted += 10;
    }
    // poisoned mutations mid-churn: both must be rejected (counted),
    // never panic the ingest lane or the engine
    engine.submit_insert(99_999, vec![f32::NAN; dim]).unwrap();
    engine.submit_delete(0).unwrap(); // already deleted before the engine started
    let responses = engine.drain(submitted);
    assert_eq!(responses.len(), submitted);
    for r in &responses {
        assert!(r.ids.len() <= 10);
        let set: std::collections::HashSet<_> = r.ids.iter().collect();
        assert_eq!(set.len(), r.ids.len(), "duplicate ids in a response");
        for id in &r.ids {
            assert!(*id >= 40, "pre-deleted id {id} served mid-churn");
        }
    }
    assert!(stressor.join().expect("stressor panicked") > 0);
    engine.quiesce_mutations();
    let stats = engine.ingest_stats();
    assert_eq!(stats.inserts, 160);
    assert_eq!(stats.deletes, 80);
    assert_eq!(stats.errors, 2, "NaN insert + double delete rejected");
    engine.shutdown();
    // quiesced: every delete is visible, recall over the live set holds
    assert_eq!(live.live_len(), 800 - 40 - 80 + 160);
    let deleted: Vec<u32> = (0..120).collect();
    let mut ctx = SearchCtx::new(0);
    for probe in [45usize, 200, 777] {
        let r = live.search(&mut ctx, &Query::new(&rows[probe]).k(20).window(80));
        for id in &r.ids {
            assert!(!deleted.contains(id), "deleted id {id} after quiesce");
        }
    }
    let corpus = live.export_live();
    let recall = live_recall(live.as_ref(), &corpus, 10, 60, 40, 5);
    assert!(recall >= 0.7, "live recall under churn too low: {recall}");
}

#[test]
fn churn_recall_within_two_points_of_fresh_rebuild() {
    // the acceptance bar: snapshot-loaded index, +20% inserts, -10%
    // deletes, then live-set recall@10 within 2 points of a fresh full
    // rebuild over the same live corpus at the same search window
    let dim = 24;
    let n0 = 1000;
    let rows = clustered_rows(n0, dim, 2);
    let snap = std::env::temp_dir().join(format!(
        "leanvec-mutate-accept-{}.leanvec",
        std::process::id()
    ));
    build(&rows, 12)
        .save(&snap, &SnapshotMeta::default())
        .unwrap();
    let (live, _meta) = LiveIndex::load(&snap).unwrap();
    std::fs::remove_file(&snap).ok();

    let mut rng = Rng::new(11);
    // +20%: new vectors from the same blob distribution
    let fresh = clustered_rows(n0 / 5, dim, 3);
    for (i, v) in fresh.iter().enumerate() {
        live.insert((n0 + i) as u32, v).unwrap();
    }
    // -10% of the *original* corpus
    let mut victims: Vec<u32> = (0..n0 as u32).collect();
    rng.shuffle(&mut victims);
    victims.truncate(n0 / 10);
    for &id in &victims {
        live.delete(id).unwrap();
    }
    let report = live.consolidate();
    assert_eq!(report.removed, n0 / 10);
    assert_eq!(live.live_len(), n0 + n0 / 5 - n0 / 10);

    let corpus = live.export_live();
    // fresh full rebuild over the live corpus, external ids == corpus
    // order mapped back through the same (ext, vector) pairs
    let rebuild_rows: Vec<Vec<f32>> = corpus.iter().map(|(_, v)| v.clone()).collect();
    let rebuilt = build(&rebuild_rows, 12);

    let (k, window, probes) = (10, 60, 100);
    let live_r = live_recall(&live, &corpus, k, window, probes, 13);
    // the rebuilt index's ids are corpus positions; rebase the oracle
    // onto positions by giving every position its own "external" id
    let pos_corpus: Vec<(u32, Vec<f32>)> = rebuild_rows
        .iter()
        .enumerate()
        .map(|(i, v)| (i as u32, v.clone()))
        .collect();
    let rebuilt_r = live_recall(&rebuilt, &pos_corpus, k, window, probes, 13);
    assert!(
        live_r >= rebuilt_r - 0.02,
        "live recall {live_r} more than 2 points below rebuild {rebuilt_r}"
    );
    assert!(live_r >= 0.85, "absolute live recall too low: {live_r}");
}

#[test]
fn mutated_snapshot_roundtrips_bit_identically() {
    let dim = 16;
    let rows = clustered_rows(400, dim, 4);
    let live = LiveIndex::from_index(build(&rows, 8));
    let mut rng = Rng::new(21);
    for i in 0..60u32 {
        let v: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 2.0).collect();
        live.insert(2000 + i, &v).unwrap();
    }
    for id in (0..100u32).step_by(3) {
        live.delete(id).unwrap();
    }
    let dir = std::env::temp_dir();
    let p1 = dir.join(format!("leanvec-mutate-rt1-{}.leanvec", std::process::id()));
    let p2 = dir.join(format!("leanvec-mutate-rt2-{}.leanvec", std::process::id()));
    let meta = SnapshotMeta {
        dataset: "soak".into(),
        seed: 9,
        scale: 1.0,
        ..SnapshotMeta::default()
    };
    live.save(&p1, &meta).unwrap();

    // a frozen-only reader must reject the live snapshot loudly
    match LeanVecIndex::load(&p1) {
        Err(SnapshotError::UnsupportedVersion { found, .. }) => assert_eq!(found, 2),
        other => panic!("frozen reader accepted a live snapshot: {other:?}"),
    }

    let (back, meta_back) = LiveIndex::load(&p1).unwrap();
    assert_eq!(meta_back.dataset, "soak");
    assert_eq!(back.live_len(), live.live_len());
    assert_eq!(back.total_slots(), live.total_slots());
    assert_eq!(back.journal(), live.journal());
    assert_eq!(back.pending_inserts(), live.pending_inserts());
    // bit-identical serving: same ids, same score bits, same stats
    let mut ctx = SearchCtx::new(0);
    for seed in 0..15u64 {
        let mut qrng = Rng::new(300 + seed);
        let q: Vec<f32> = (0..dim).map(|_| qrng.gaussian_f32() * 2.0).collect();
        let query = Query::new(&q).k(10).window(50).rerank_window(80);
        let a = live.search(&mut ctx, &query);
        let b = back.search(&mut ctx, &query);
        assert_eq!(a.ids, b.ids);
        let (sa, sb): (Vec<u32>, Vec<u32>) = (
            a.scores.iter().map(|s| s.to_bits()).collect(),
            b.scores.iter().map(|s| s.to_bits()).collect(),
        );
        assert_eq!(sa, sb);
        assert_eq!(a.stats, b.stats);
    }
    // byte-deterministic re-save
    back.save(&p2, &meta_back).unwrap();
    let (b1, b2) = (std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    assert_eq!(b1, b2, "save -> load -> save changed bytes");
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p2).ok();
}

#[test]
fn consolidated_snapshot_preserves_external_ids() {
    let dim = 12;
    let rows = clustered_rows(300, dim, 6);
    let live = LiveIndex::from_index(build(&rows, 6));
    for id in (0..300u32).step_by(4) {
        live.delete(id).unwrap();
    }
    live.consolidate();
    let path = std::env::temp_dir().join(format!(
        "leanvec-mutate-consol-{}.leanvec",
        std::process::id()
    ));
    live.save(&path, &SnapshotMeta::default()).unwrap();
    let (back, _) = LiveIndex::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back.total_slots(), live.total_slots());
    assert_eq!(back.journal().consolidations, 1);
    let mut ctx = SearchCtx::new(0);
    // surviving ids keep answering under their original external names
    for probe in [1u32, 7, 150, 299] {
        if probe % 4 == 0 {
            continue;
        }
        let r = back.search(&mut ctx, &Query::new(&rows[probe as usize]).k(1).window(40));
        assert_eq!(r.ids, vec![probe], "self-query after consolidated reload");
    }
    // deleted ids are gone even though the tombstone bitmap is empty
    let r = back.search(&mut ctx, &Query::new(&rows[0]).k(20).window(80));
    assert!(r.ids.iter().all(|id| id % 4 != 0));
    assert_eq!(r.stats.deleted_skipped, 0, "compaction left no tombstones");
}

#[test]
fn pristine_live_save_is_a_frozen_snapshot() {
    let rows = clustered_rows(200, 12, 8);
    let live = LiveIndex::from_index(build(&rows, 6));
    let path = std::env::temp_dir().join(format!(
        "leanvec-mutate-pristine-{}.leanvec",
        std::process::id()
    ));
    live.save(&path, &SnapshotMeta::default()).unwrap();
    // no mutation history -> plain version-1 file any reader loads
    let (frozen, _) = LeanVecIndex::load(&path).unwrap();
    assert_eq!(frozen.len(), 200);
    let (live_back, _) = LiveIndex::load(&path).unwrap();
    assert_eq!(live_back.live_len(), 200);
    std::fs::remove_file(&path).ok();
}
