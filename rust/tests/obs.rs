//! Observability integration: histogram accuracy against exact
//! percentiles, concurrent record/snapshot soak, label cardinality
//! caps, the engine's metrics exposition round-tripping through the
//! strict parser, and the flight recorder catching deliberately slow
//! queries under load.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig, QuerySpec};
use leanvec::data::synth::{generate, QueryDist, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use leanvec::obs::{self, Registry, ValueSnap, MAX_CHILDREN, OVERFLOW_LABEL};
use leanvec::util::rng::Rng;
use leanvec::util::stats::Summary;
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------
// histogram accuracy
// ---------------------------------------------------------------------

/// The histogram's quantile convention: rank = ceil(q * n), clamped to
/// [1, n], value at that rank. Comparing against this isolates pure
/// bucket-resolution error from rank-convention differences.
fn rank_quantile(sorted: &[u64], q: f64) -> f64 {
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1] as f64
}

/// Record `vals` into a detached histogram and assert every quantile
/// lands within `tol` relative error of the exact rank quantile, and
/// that the sum is exact.
fn check_accuracy(vals: &[u64], tol: f64, what: &str) {
    let h = obs::Histogram::detached(1.0);
    let mut sorted = vals.to_vec();
    for &v in vals {
        h.record(v);
    }
    sorted.sort_unstable();
    let snap = h.snapshot();
    assert_eq!(snap.count(), vals.len() as u64, "{what}: count");
    let sum_exact: f64 = vals.iter().map(|&v| v as f64).sum();
    assert!(
        (snap.sum() - sum_exact).abs() <= 1e-9 * sum_exact.max(1.0),
        "{what}: sum {} want {sum_exact}",
        snap.sum()
    );
    for q in [0.5, 0.9, 0.99, 0.999] {
        let exact = rank_quantile(&sorted, q);
        let got = snap.quantile(q);
        let rel = (got - exact).abs() / exact.max(1.0);
        assert!(
            rel <= tol,
            "{what}: q={q} got {got} want {exact} (rel {rel:.4} > {tol})"
        );
    }
}

#[test]
fn histogram_accuracy_on_adversarial_distributions() {
    let mut rng = Rng::new(0x0B5);

    // uniform over three decades
    let uniform: Vec<u64> = (0..20_000).map(|_| 100 + rng.below(999_900) as u64).collect();
    check_accuracy(&uniform, 0.025, "uniform");

    // heavy power-law tail: exact bucket mids must track huge jumps
    let powers: Vec<u64> = (0..20_000).map(|i| 1u64 << (7 + (i * 7919) % 20)).collect();
    check_accuracy(&powers, 0.025, "powers-of-two");

    // bimodal with a 1000x gap between modes (30% fast / 70% slow)
    let bimodal: Vec<u64> = (0..10_000)
        .map(|i| if i % 10 < 3 { 1_000 + (i as u64 % 97) } else { 1_000_000 + (i as u64 % 9973) })
        .collect();
    check_accuracy(&bimodal, 0.025, "bimodal");

    // constant stream (every quantile is the one value)
    check_accuracy(&vec![123_456u64; 5_000], 0.025, "constant");

    // tiny values sit in exact width-1 buckets: absolute error <= 0.5
    let small: Vec<u64> = (0..5_000).map(|_| rng.below(32) as u64).collect();
    let h = obs::Histogram::detached(1.0);
    let mut sorted = small.clone();
    for &v in &small {
        h.record(v);
    }
    sorted.sort_unstable();
    let snap = h.snapshot();
    for q in [0.5, 0.99] {
        let exact = rank_quantile(&sorted, q);
        let got = snap.quantile(q);
        assert!(
            (got - exact).abs() <= 0.5 + 1e-9,
            "small values: q={q} got {got} want {exact}"
        );
    }
}

#[test]
fn histogram_tracks_summary_on_smooth_distributions() {
    // against the interpolating reference implementation the bench
    // reports used before the registry existed: on smooth, dense
    // distributions the two quantile code paths must agree closely
    let mut rng = Rng::new(0x57A7);
    let h = obs::Histogram::detached(1.0);
    let mut s = Summary::new();
    for _ in 0..50_000 {
        // folded-gaussian latency shape, mean ~1ms in ns, >= 100us
        let v = 100_000.0 + (rng.gaussian().abs() * 900_000.0);
        h.record(v as u64);
        s.push(v.trunc());
    }
    let snap = h.snapshot();
    for (q, exact) in [(0.5, s.p50()), (0.99, s.p99())] {
        let got = snap.quantile(q);
        let rel = (got - exact).abs() / exact;
        assert!(rel <= 0.05, "q={q} got {got} want {exact} (rel {rel:.4})");
    }
    assert!((snap.mean() - s.mean()).abs() / s.mean() <= 1e-3);
}

// ---------------------------------------------------------------------
// registry concurrency + cardinality
// ---------------------------------------------------------------------

#[test]
fn concurrent_record_and_snapshot_soak() {
    const WRITERS: u64 = 4;
    const PER_WRITER: u64 = 50_000;
    let r = Registry::new(true);
    let h = r.register_histogram("leanvec_test_soak_seconds", "race soak", 1.0);
    let c = r.register_counter("leanvec_test_soak_total", "race soak");
    std::thread::scope(|s| {
        for t in 0..WRITERS {
            let h = h.clone();
            let c = c.clone();
            s.spawn(move || {
                for i in 0..PER_WRITER {
                    // values in [1, 1000]: snapshots can bound the sum
                    h.record((t * PER_WRITER + i) % 1_000 + 1);
                    c.inc();
                }
            });
        }
        // reader races the writers: every observed snapshot must be
        // internally consistent (no torn counts, monotone growth)
        let reader = &r;
        s.spawn(move || {
            let mut last_count = 0u64;
            for _ in 0..300 {
                for fam in reader.snapshot() {
                    if fam.name != "leanvec_test_soak_seconds" {
                        continue;
                    }
                    for (_, v) in &fam.children {
                        if let ValueSnap::Hist(snap) = v {
                            let n = snap.count();
                            assert!(n <= WRITERS * PER_WRITER, "count overshot: {n}");
                            assert!(n >= last_count, "count went backwards");
                            last_count = n;
                            // sum and buckets are separate relaxed
                            // atomics: up to one in-flight sample per
                            // writer may straddle the snapshot
                            let slack = WRITERS as f64 * 1_000.0;
                            assert!(snap.sum() >= n as f64 - slack, "sum below count*min");
                            assert!(
                                snap.sum() <= n as f64 * 1_000.0 + slack,
                                "sum above count*max"
                            );
                        }
                    }
                }
            }
        });
    });
    assert_eq!(h.snapshot().count(), WRITERS * PER_WRITER);
    assert_eq!(c.get(), WRITERS * PER_WRITER);
}

#[test]
fn label_cardinality_is_capped() {
    let r = Registry::new(true);
    let fam = r.register_counter_family("leanvec_test_tenants_total", "cap", "collection");
    for i in 0..200 {
        fam.with(&format!("tenant-{i}")).inc();
    }
    // distinct children never exceed the cap (+1 for the overflow
    // child) no matter how many label values a hostile client sends
    let kids = r.child_count("leanvec_test_tenants_total");
    assert!(kids <= MAX_CHILDREN + 1, "cardinality leak: {kids} children");
    let snap = r.snapshot();
    let f = snap
        .iter()
        .find(|f| f.name == "leanvec_test_tenants_total")
        .expect("family snapshotted");
    let mut total = 0u64;
    let mut overflow = 0u64;
    for (labels, v) in &f.children {
        if let ValueSnap::Counter(n) = v {
            total += n;
            if matches!(labels, Some((_, value)) if value == OVERFLOW_LABEL) {
                overflow += n;
            }
        }
    }
    assert_eq!(total, 200, "no increment may be dropped");
    assert!(
        overflow >= 200 - MAX_CHILDREN as u64,
        "overflow child absorbed only {overflow}"
    );
}

// ---------------------------------------------------------------------
// engine-level: exposition round-trip + flight recorder
// ---------------------------------------------------------------------

fn dataset(n: usize) -> leanvec::data::synth::Dataset {
    generate(&SynthSpec {
        name: "obs".into(),
        dim: 64,
        n,
        n_learn_queries: 150,
        n_test_queries: 80,
        similarity: Similarity::InnerProduct,
        queries: QueryDist::OutOfDistribution(0.6),
        decay: 0.6,
        seed: 0x0B5,
    })
}

fn build(ds: &leanvec::data::synth::Dataset) -> Arc<leanvec::index::leanvec_index::LeanVecIndex> {
    let mut gp = GraphParams::for_similarity(ds.similarity);
    gp.max_degree = 16;
    gp.build_window = 32;
    Arc::new(
        IndexBuilder::new()
            .projection(ProjectionKind::OodEigSearch)
            .target_dim(24)
            .primary(Compression::Lvq8)
            .secondary(Compression::F16)
            .graph_params(gp)
            .build(&ds.database, Some(&ds.learn_queries), ds.similarity),
    )
}

#[test]
fn engine_exposition_round_trips_and_names_conform() {
    leanvec::obs::set_enabled(true);
    let ds = dataset(1_200);
    let index = build(&ds);
    let engine = Engine::start(
        index,
        EngineConfig {
            workers: 1,
            ..Default::default()
        },
    );
    let n = 120;
    for i in 0..n {
        engine
            .submit(ds.test_queries[i % ds.test_queries.len()].clone(), 5)
            .expect("engine running");
    }
    let responses = engine.drain(n);
    assert_eq!(responses.len(), n);

    let text = engine.metrics_text();
    let families = leanvec::obs::expo::parse_text(&text).expect("strict parse of our own dump");
    assert!(families.len() >= 20, "catalog missing: {} families", families.len());
    // every exposed family obeys the naming convention the lint rule
    // enforces at the source level (test registries excepted)
    for f in families.iter().filter(|f| !f.name.contains("_test_")) {
        assert!(
            leanvec::analysis::metric_name_ok(&f.name),
            "exposed name breaks convention: {}",
            f.name
        );
    }
    // the counters moved: this engine answered at least n queries
    let q = families
        .iter()
        .find(|f| f.name == "leanvec_engine_queries_total")
        .expect("queries counter exposed");
    let served: f64 = q.samples.iter().map(|s| s.value).sum();
    assert!(served >= n as f64, "served {served} < {n}");
    // e2e summary carries quantiles + sum + count for the collection
    let e2e = families
        .iter()
        .find(|f| f.name == "leanvec_engine_e2e_seconds")
        .expect("e2e histogram exposed");
    assert_eq!(e2e.kind, "summary");
    assert!(e2e.samples.iter().any(|s| s.name.ends_with("_count") && s.value >= n as f64));

    // the JSON exposition parses as JSON and carries the same families
    let json = leanvec::util::json::Json::parse(&engine.metrics_json()).expect("valid json");
    let fams = json.get("families").and_then(|f| f.as_arr()).expect("families array");
    assert!(fams.len() >= 20);
    engine.shutdown();
}

#[test]
fn flight_recorder_captures_artificially_slow_queries() {
    leanvec::obs::set_enabled(true);
    let ds = dataset(1_500);
    let index = build(&ds);
    let engine = Engine::start(
        index,
        EngineConfig {
            workers: 1,
            batch: BatchPolicy {
                max_batch: 8,
                max_wait: Duration::from_micros(100),
            },
            search: SearchParams {
                window: 10,
                rerank_window: 10,
            },
            ..Default::default()
        },
    );
    // closed loop (drain each response before the next submit) so queue
    // wait stays flat and e2e differences come from the search itself:
    // every 11th query runs with a ~60x wider window -> reliably slow
    const SLOW_WINDOW: usize = 600;
    let mut submitted_slow = 0u64;
    for i in 0..220usize {
        let q = ds.test_queries[i % ds.test_queries.len()].clone();
        let spec = if i % 11 == 0 {
            submitted_slow += 1;
            QuerySpec::top_k(5)
                .with_window(SLOW_WINDOW)
                .with_rerank_window(SLOW_WINDOW)
        } else {
            QuerySpec::top_k(5)
        };
        engine.submit_spec(q, spec).expect("engine running");
        assert_eq!(engine.drain(1).len(), 1);
    }
    let records = engine.flight_records();
    engine.shutdown();
    assert!(!records.is_empty(), "flight recorder stayed empty");
    // the deliberately slowed queries dominate the slow ring: the ring
    // has 48 slow slots and only 20 queries were slowed, so (nearly)
    // all of them must have been kept
    let slow_kept = records
        .iter()
        .filter(|r| r.params.window == SLOW_WINDOW)
        .count() as u64;
    assert!(
        slow_kept >= submitted_slow / 2,
        "kept {slow_kept} of {submitted_slow} slowed queries"
    );
    // records carry a usable per-stage breakdown
    for r in &records {
        assert!(r.e2e_seconds > 0.0);
        assert!(r.search_seconds <= r.e2e_seconds + 1e-9);
        assert!(!r.collection.is_empty());
        // Display stays total (no panics, mentions the request id)
        assert!(format!("{r}").contains(&format!("req {}", r.id)));
    }
    // slowest-first ordering
    for pair in records.windows(2) {
        assert!(pair[0].e2e_seconds >= pair[1].e2e_seconds);
    }
}
