//! Sharded serving integration: scatter-gather equivalence against the
//! exact oracle, sharded-vs-unsharded recall parity, shard-directory
//! snapshot round trips, mutation routing under churn, and a
//! multi-collection multi-tenant soak through the engine.

use leanvec::config::{BuildParams, Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::coordinator::{BatchPolicy, Engine, EngineConfig, EngineError, QuerySpec};
use leanvec::data::gt::ground_truth;
use leanvec::data::synth::{generate, QueryDist, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::SearchParams;
use leanvec::index::persist::SnapshotMeta;
use leanvec::index::query::{Query, SearchResult, VectorIndex};
use leanvec::index::FlatIndex;
use leanvec::shard::{
    merge_top_k, shard_of, Collection, CollectionRegistry, ShardSpec, ShardedIndex, TenantQuota,
    DEFAULT_HASH_SEED, MANIFEST_NAME,
};
use std::path::PathBuf;
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("leanvec-shard-{}-{name}", std::process::id()))
}

fn dataset(name: &str, n: usize, dim: usize, seed: u64) -> leanvec::data::synth::Dataset {
    generate(&SynthSpec {
        name: name.into(),
        dim,
        n,
        n_learn_queries: 200,
        n_test_queries: 60,
        similarity: Similarity::InnerProduct,
        queries: QueryDist::OutOfDistribution(0.6),
        decay: 0.6,
        seed,
    })
}

fn configure(b: IndexBuilder) -> IndexBuilder {
    let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
    gp.max_degree = 24;
    gp.build_window = 60;
    b.projection(ProjectionKind::OodEigSearch)
        .target_dim(24)
        .primary(Compression::Lvq8)
        .secondary(Compression::F16)
        .graph_params(gp)
}

fn recall_at_k(results: &[SearchResult], truth: &[Vec<u32>], k: usize) -> f64 {
    let mut hits = 0usize;
    for (r, t) in results.iter().zip(truth) {
        let tk = &t[..k.min(t.len())];
        hits += r.ids.iter().take(k).filter(|id| tk.contains(id)).count();
    }
    hits as f64 / (results.len() * k) as f64
}

/// The scatter-gather merge against the exact oracle: hash-partition the
/// corpus, run the exact flat scan per partition, merge with the same
/// `merge_top_k` the serving path uses, and the result must be
/// IDENTICAL to the unsharded flat top-k — sharding cannot change exact
/// answers, only partition the work.
#[test]
fn flat_oracle_sharded_merge_matches_unsharded_exactly() {
    let ds = dataset("shard-oracle", 1_500, 48, 11);
    let shards = 4;
    let k = 10;
    // partition external ids exactly as ShardSpec routing would
    let mut parts: Vec<Vec<u32>> = vec![Vec::new(); shards];
    for id in 0..ds.database.len() as u32 {
        parts[shard_of(id, DEFAULT_HASH_SEED, shards)].push(id);
    }
    let flats: Vec<(FlatIndex, Vec<u32>)> = parts
        .into_iter()
        .map(|ext_of| {
            let rows: Vec<Vec<f32>> = ext_of
                .iter()
                .map(|&id| ds.database[id as usize].clone())
                .collect();
            (FlatIndex::new(&rows, ds.similarity), ext_of)
        })
        .collect();
    let oracle = FlatIndex::new(&ds.database, ds.similarity);
    for q in &ds.test_queries {
        let per_shard: Vec<SearchResult> = flats
            .iter()
            .map(|(flat, ext_of)| {
                let mut r = flat.search_one(&Query::new(q).k(k));
                for id in r.ids.iter_mut() {
                    *id = ext_of[*id as usize];
                }
                r
            })
            .collect();
        let merged = merge_top_k(per_shard, k);
        let exact = oracle.search_one(&Query::new(q).k(k));
        assert_eq!(merged.ids, exact.ids, "sharded exact != unsharded exact");
        assert_eq!(merged.scores, exact.scores);
    }
}

/// shards=1 through `ShardedIndex` is the unsharded index: same single
/// graph, same traversal, so recall matches a direct build; shards=4
/// holds recall@10 within a point of shards=1 at the same window (each
/// shard searches its whole sub-corpus with the full window, so the
/// union can only widen the candidate set).
#[test]
fn sharded_recall_within_one_point_of_unsharded() {
    let ds = dataset("shard-recall", 2_400, 64, 12);
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let run = |ix: &ShardedIndex| -> Vec<SearchResult> {
        ds.test_queries
            .iter()
            .map(|v| {
                let q = Query::new(v).k(k).window(100).rerank_window(120);
                let q_proj = ix.model().project_query(v);
                ix.search_scatter(&q_proj, &q)
            })
            .collect()
    };
    let one = ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(1),
        0,
        configure,
    );
    let four = ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(4),
        0,
        configure,
    );
    let r1 = recall_at_k(&run(&one), &truth, k);
    let r4 = recall_at_k(&run(&four), &truth, k);
    assert!(r1 >= 0.85, "unsharded recall too low to compare: {r1}");
    assert!(
        r4 >= r1 - 0.01,
        "shards=4 recall {r4} dropped more than a point below shards=1 {r1}"
    );
}

/// Shard-directory persistence: save_dir -> load_dir round-trips the
/// manifest + per-shard snapshots and the loaded index serves
/// bit-identical results (ids AND scores) to the in-memory build.
#[test]
fn shard_dir_round_trip_serves_bit_identically() {
    let ds = dataset("shard-persist", 900, 48, 13);
    let ix = ShardedIndex::build(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(3),
        1,
        configure,
    );
    let dir = tmp_dir("roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    let meta = SnapshotMeta {
        dataset: ds.name.clone(),
        seed: 13,
        scale: 1.0,
        build: BuildParams { build_threads: 1 },
        search_defaults: SearchParams {
            window: 64,
            rerank_window: 80,
        },
    };
    let bytes = ix.save_dir(&dir, &meta).expect("save_dir");
    assert!(bytes > 0);
    assert!(dir.join(MANIFEST_NAME).is_file(), "manifest written");
    let (loaded, meta2) = ShardedIndex::load_dir(&dir).expect("load_dir");
    assert_eq!(meta2.dataset, meta.dataset);
    assert_eq!(meta2.seed, meta.seed);
    assert_eq!(meta2.search_defaults.window, 64);
    assert_eq!(loaded.shards(), 3);
    assert_eq!(VectorIndex::len(&loaded), VectorIndex::len(&ix));
    assert_eq!(loaded.spec().hash_seed, ix.spec().hash_seed);
    for v in ds.test_queries.iter().take(30) {
        let q = Query::new(v).k(10).window(64);
        let q_proj = ix.model().project_query(v);
        let a = ix.search_scatter(&q_proj, &q);
        let b = loaded.search_scatter(&loaded.model().project_query(v), &q);
        assert_eq!(a, b, "round-tripped shard set must serve identically");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mutation churn across a live shard set: every delete routes to its
/// hash shard, and no deleted external id is ever served afterwards —
/// across every shard, with inserts landing in between.
#[test]
fn churn_across_shards_never_serves_a_deleted_id() {
    let ds = dataset("shard-churn", 1_200, 48, 14);
    let ix = ShardedIndex::build_live(
        &ds.database,
        Some(&ds.learn_queries),
        ds.similarity,
        ShardSpec::new(3),
        1,
        configure,
    );
    let n = ds.database.len() as u32;
    // interleave deletes of every 5th id with re-inserts under new ids
    let mut deleted = Vec::new();
    for (step, id) in (0..n).step_by(5).enumerate() {
        ix.delete(id).expect("delete routed to its shard");
        deleted.push(id);
        if step % 3 == 0 {
            let fresh = n + step as u32;
            ix.insert(fresh, &ds.database[id as usize])
                .expect("insert routed to its shard");
            assert!(ix.contains(fresh));
        }
    }
    for &id in &deleted {
        assert!(!ix.contains(id), "deleted id {id} still live");
    }
    let deleted_set: std::collections::HashSet<u32> = deleted.iter().copied().collect();
    for v in ds.test_queries.iter().take(40) {
        let q = Query::new(v).k(10).window(100);
        let r = ix.search_scatter(&ix.model().project_query(v), &q);
        assert!(!r.ids.is_empty());
        for id in &r.ids {
            assert!(
                !deleted_set.contains(id),
                "deleted id {id} served from shard {}",
                ix.shard_for(*id)
            );
        }
    }
}

/// Multi-collection, multi-tenant soak through the engine: a frozen
/// 2-shard collection and a live 3-shard collection served together,
/// with searches racing the ingest lane's churn + staggered
/// consolidation on the live tenant. Checks routing isolation, quota
/// bookkeeping, and that tombstoned ids never escape.
#[test]
fn multi_tenant_churn_soak_across_collections() {
    let ds_a = dataset("soak-a", 900, 48, 15);
    let ds_b = dataset("soak-b", 900, 48, 16);
    let frozen = ShardedIndex::build(
        &ds_a.database,
        Some(&ds_a.learn_queries),
        ds_a.similarity,
        ShardSpec::new(2),
        1,
        configure,
    );
    let live = ShardedIndex::build_live(
        &ds_b.database,
        Some(&ds_b.learn_queries),
        ds_b.similarity,
        ShardSpec::new(3),
        1,
        configure,
    );
    let mut registry = CollectionRegistry::new();
    registry.register(
        Collection::new("tenant-a", frozen).with_defaults(SearchParams {
            window: 80,
            rerank_window: 80,
        }),
    );
    registry.register(
        Collection::new("tenant-b", live)
            .with_defaults(SearchParams {
                window: 80,
                rerank_window: 80,
            })
            .with_quota(TenantQuota {
                max_inflight: 0, // unlimited searches
                max_pending_mutations: 4_096,
            }),
    );
    let mut engine = Engine::start_collections(
        registry,
        EngineConfig {
            workers: 2,
            batch: BatchPolicy {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
            consolidate_threshold: 0.05,
            ..EngineConfig::default()
        },
    );

    // interleave: churn tenant-b while searching both tenants
    let n_rounds = 60;
    let mut submitted = 0usize;
    let mut deleted = Vec::new();
    for round in 0..n_rounds {
        let del = (round * 7) as u32 % 900;
        if engine.submit_delete_to("tenant-b", del).is_ok() {
            deleted.push(del);
        }
        engine
            .submit_insert_to("tenant-b", 10_000 + round as u32, ds_b.database[del as usize].clone())
            .expect("insert admitted");
        let qa = &ds_a.test_queries[round % ds_a.test_queries.len()];
        let qb = &ds_b.test_queries[round % ds_b.test_queries.len()];
        engine
            .submit_spec(qa.clone(), QuerySpec::top_k(10).with_collection("tenant-a"))
            .unwrap();
        engine
            .submit_spec(qb.clone(), QuerySpec::top_k(10).with_collection("tenant-b"))
            .unwrap();
        submitted += 2;
    }
    let responses = engine.drain(submitted);
    assert_eq!(responses.len(), submitted);
    for r in &responses {
        assert_eq!(r.ids.len(), 10);
    }
    // unknown collections stay unknown even mid-soak
    assert_eq!(
        engine.submit_spec(ds_a.test_queries[0].clone(), QuerySpec::top_k(5)),
        Err(EngineError::UnknownCollection("default".into()))
    );
    // settle the ingest lane, then verify the churn landed
    engine.quiesce_mutations();
    let ingest = engine.ingest_stats().snapshot();
    assert_eq!(ingest.inserts, n_rounds);
    assert!(ingest.deletes >= deleted.len() - 5, "most deletes applied");
    let b = engine.collection("tenant-b").expect("registered").clone();
    let bix = b.index();
    let deleted_set: std::collections::HashSet<u32> =
        deleted.iter().copied().filter(|id| !bix.contains(*id)).collect();
    // post-quiesce searches still work and never serve a tombstoned id
    for v in ds_b.test_queries.iter().take(20) {
        let q = Query::new(v).k(10).window(100);
        let r = bix.search_scatter(&bix.model().project_query(v), &q);
        for id in &r.ids {
            assert!(!deleted_set.contains(id), "tombstoned id {id} served");
        }
    }
    // admission bookkeeping: all searches drained -> nothing in flight
    let a = engine.collection("tenant-a").expect("registered");
    assert_eq!(
        a.admission().inflight.load(std::sync::atomic::Ordering::Acquire),
        0
    );
    assert_eq!(
        b.admission().pending_mutations.load(std::sync::atomic::Ordering::Acquire),
        0
    );
    assert!(b.admission().mutations.load(std::sync::atomic::Ordering::Relaxed) >= n_rounds as u64);
    let leftovers = engine.shutdown();
    assert!(leftovers.is_empty(), "everything drained before shutdown");
}

/// Per-tenant quota isolation through the engine: a tiny mutation quota
/// on one collection rejects with `QuotaExceeded` without touching the
/// other collection's admission.
#[test]
fn quota_exceeded_isolated_per_collection() {
    let ds = dataset("shard-quota", 600, 48, 17);
    let mk_live = || {
        ShardedIndex::build_live(
            &ds.database,
            Some(&ds.learn_queries),
            ds.similarity,
            ShardSpec::new(2),
            1,
            configure,
        )
    };
    let mut registry = CollectionRegistry::new();
    registry.register(Collection::new("small", mk_live()).with_quota(TenantQuota {
        max_inflight: 0,
        max_pending_mutations: 1,
    }));
    registry.register(Collection::new("big", mk_live()));
    let mut engine = Engine::start_collections(
        registry,
        EngineConfig {
            workers: 1,
            ..EngineConfig::default()
        },
    );
    // backlog the single ingest lane with "big" mutations, then submit
    // two to "small": the first fills its 1-deep pending quota, and the
    // second must reject because the lane is still chewing the backlog.
    for i in 0..400u32 {
        engine.submit_delete_to("big", i % 600).expect("big unbounded");
    }
    let mut rejected = 0usize;
    for i in 0..8u32 {
        match engine.submit_delete_to("small", i) {
            Ok(()) => {}
            Err(EngineError::QuotaExceeded { collection }) => {
                assert_eq!(collection, "small");
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert!(rejected > 0, "a 1-deep mutation quota never rejected behind a 400-deep backlog");
    let small = engine.collection("small").expect("registered");
    assert!(small.admission().rejected.load(std::sync::atomic::Ordering::Relaxed) >= rejected as u64);
    let big = engine.collection("big").expect("registered");
    assert_eq!(big.admission().rejected.load(std::sync::atomic::Ordering::Relaxed), 0);
    engine.quiesce_mutations();
    engine.shutdown();
}
