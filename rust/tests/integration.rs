//! Cross-module integration tests: dataset -> training -> index ->
//! search -> recall, across similarities, learners and compressions.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::data::gt::{ground_truth, recall_at_k};
use leanvec::data::synth::{generate, QueryDist, SynthSpec};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::query::{Query, VectorIndex};

fn spec(sim: Similarity, queries: QueryDist, dim: usize, n: usize) -> SynthSpec {
    SynthSpec {
        name: "itest".into(),
        dim,
        n,
        n_learn_queries: 256,
        n_test_queries: 128,
        similarity: sim,
        queries,
        decay: 0.6,
        seed: 42,
    }
}

fn small_graph(sim: Similarity) -> GraphParams {
    let mut gp = GraphParams::for_similarity(sim);
    gp.max_degree = 24;
    gp.build_window = 48;
    gp
}

fn end_to_end_recall(
    sim: Similarity,
    queries: QueryDist,
    projection: ProjectionKind,
    d: usize,
    primary: Compression,
    secondary: Compression,
) -> f64 {
    let ds = generate(&spec(sim, queries, 128, 2_500));
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let index = IndexBuilder::new()
        .projection(projection)
        .target_dim(d)
        .primary(primary)
        .secondary(secondary)
        .graph_params(small_graph(sim))
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let got: Vec<Vec<u32>> = ds
        .test_queries
        .iter()
        .map(|q| index.search_one(&Query::new(q).k(k).window(80)).ids)
        .collect();
    recall_at_k(&got, &truth, k)
}

#[test]
fn leanvec_ood_high_recall_on_ood_ip() {
    let r = end_to_end_recall(
        Similarity::InnerProduct,
        QueryDist::OutOfDistribution(0.7),
        ProjectionKind::OodEigSearch,
        48,
        Compression::Lvq8,
        Compression::F16,
    );
    assert!(r >= 0.85, "recall {r}");
}

#[test]
fn leanvec_id_high_recall_on_id_l2() {
    let r = end_to_end_recall(
        Similarity::L2,
        QueryDist::InDistribution,
        ProjectionKind::Id,
        48,
        Compression::Lvq8,
        Compression::F16,
    );
    assert!(r >= 0.85, "recall {r}");
}

#[test]
fn cosine_similarity_end_to_end() {
    let r = end_to_end_recall(
        Similarity::Cosine,
        QueryDist::InDistribution,
        ProjectionKind::Id,
        48,
        Compression::Lvq8,
        Compression::F16,
    );
    assert!(r >= 0.85, "recall {r}");
}

#[test]
fn ood_learner_beats_id_learner_on_ood_data() {
    let r_ood = end_to_end_recall(
        Similarity::InnerProduct,
        QueryDist::OutOfDistribution(0.9),
        ProjectionKind::OodEigSearch,
        32,
        Compression::Lvq8,
        Compression::F16,
    );
    let r_id = end_to_end_recall(
        Similarity::InnerProduct,
        QueryDist::OutOfDistribution(0.9),
        ProjectionKind::Id,
        32,
        Compression::Lvq8,
        Compression::F16,
    );
    // the paper's headline OOD accuracy gap (Fig. 5 / Fig. 11)
    assert!(
        r_ood >= r_id - 0.01,
        "ood learner {r_ood} should not lose to id learner {r_id}"
    );
}

#[test]
fn lvq4_primary_still_searches() {
    let r = end_to_end_recall(
        Similarity::InnerProduct,
        QueryDist::InDistribution,
        ProjectionKind::Id,
        48,
        Compression::Lvq4,
        Compression::F16,
    );
    assert!(r >= 0.75, "recall {r}");
}

#[test]
fn no_reduction_fp16_baseline_works() {
    let r = end_to_end_recall(
        Similarity::L2,
        QueryDist::InDistribution,
        ProjectionKind::None,
        0,
        Compression::F16,
        Compression::F16,
    );
    assert!(r >= 0.9, "recall {r}");
}

#[test]
fn rerank_recovers_projection_loss() {
    // aggressive reduction (128 -> 16): primary-only recall collapses,
    // rerank restores it (Fig. 11's mechanism)
    let ds = generate(&spec(
        Similarity::InnerProduct,
        QueryDist::InDistribution,
        128,
        2_000,
    ));
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let index = IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(16)
        .graph_params(small_graph(ds.similarity))
        .build(&ds.database, Some(&ds.learn_queries), ds.similarity);
    let mut ctx = leanvec::graph::beam::SearchCtx::new(index.len());
    let mut got_rr = Vec::new();
    let mut got_nr = Vec::new();
    for q in &ds.test_queries {
        got_rr.push(index.search(&mut ctx, &Query::new(q).k(k).window(100)).ids);
        got_nr.push(
            index
                .search(&mut ctx, &Query::new(q).k(k).window(100).no_rerank())
                .ids,
        );
    }
    let r_rr = recall_at_k(&got_rr, &truth, k);
    let r_nr = recall_at_k(&got_nr, &truth, k);
    assert!(
        r_rr >= r_nr + 0.05,
        "rerank {r_rr} should clearly beat no-rerank {r_nr} at 8x reduction"
    );
}

#[test]
fn build_and_search_deterministic_for_seed() {
    let ds = generate(&spec(
        Similarity::InnerProduct,
        QueryDist::InDistribution,
        64,
        1_500,
    ));
    let build = || {
        IndexBuilder::new()
            .projection(ProjectionKind::Id)
            .target_dim(24)
            .graph_params(small_graph(ds.similarity))
            .seed(123)
            .build(&ds.database, None, ds.similarity)
    };
    let (a, b) = (build(), build());
    for q in ds.test_queries.iter().take(10) {
        let query = Query::new(q).k(10).window(50);
        assert_eq!(a.search_one(&query).ids, b.search_one(&query).ids);
    }
}

#[test]
fn graph_quality_preserved_under_reduction() {
    // Fig. 14: graph built on reduced+quantized vectors reaches the same
    // recall as one built on full vectors (searched identically)
    let ds = generate(&spec(
        Similarity::InnerProduct,
        QueryDist::InDistribution,
        96,
        2_000,
    ));
    let k = 10;
    let truth = ground_truth(&ds.database, &ds.test_queries, k, ds.similarity);
    let reduced = IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(32)
        .graph_params(small_graph(ds.similarity))
        .build(&ds.database, None, ds.similarity);
    let full = IndexBuilder::new()
        .projection(ProjectionKind::None)
        .graph_params(small_graph(ds.similarity))
        .build(&ds.database, None, ds.similarity);
    let recall = |ix: &leanvec::index::leanvec_index::LeanVecIndex| {
        let got: Vec<Vec<u32>> = ds
            .test_queries
            .iter()
            .map(|q| ix.search_one(&Query::new(q).k(k).window(80)).ids)
            .collect();
        recall_at_k(&got, &truth, k)
    };
    let (r_red, r_full) = (recall(&reduced), recall(&full));
    assert!(
        r_red >= r_full - 0.05,
        "reduced-graph recall {r_red} vs full-graph {r_full}"
    );
}
