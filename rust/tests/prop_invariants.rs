//! Property-based invariants (custom mini-framework in util::prop;
//! proptest is unavailable offline). Covers quantization, projection,
//! graph, search-buffer and coordinator invariants.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::make_store;
use leanvec::index::query::{Query, VectorIndex};
use leanvec::linalg::matrix::dot;
use leanvec::prop_assert;
use leanvec::quant::ScoreStore;
use leanvec::util::prop::{check, Config, Gen};

fn rows_from(g: &mut Gen, n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n).map(|_| g.vec_gaussian(d)).collect()
}

#[test]
fn prop_lvq_roundtrip_error_bounded() {
    check("lvq-roundtrip", Config::default(), |g| {
        let n = g.usize_in(2, 40);
        let d = g.usize_in(2, 96);
        let bits = if g.usize_in(0, 1) == 0 { 4u8 } else { 8u8 };
        let rows = rows_from(g, n, d);
        let store = leanvec::quant::LvqStore::new(&rows, bits);
        // per-vector max error <= delta/2 + f32 noise, delta = range/(2^B-1)
        for (i, r) in rows.iter().enumerate() {
            let dec = store.decode(i as u32);
            let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            // mean removal can widen the per-vector range by the mean's
            // own range; bound via the global range of the row set
            let levels = (1u32 << bits) as f32 - 1.0;
            let bound = 2.0 * (hi - lo).max(1e-6) / levels + 1e-3;
            for (a, b) in dec.iter().zip(r.iter()) {
                prop_assert!(
                    (a - b).abs() <= bound * 4.0,
                    "decode error {} > {} (bits {bits})",
                    (a - b).abs(),
                    bound * 4.0
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lvq_score_equals_decode_dot() {
    check("lvq-score-decode", Config::default(), |g| {
        let n = g.usize_in(2, 30);
        let d = g.usize_in(2, 64);
        let rows = rows_from(g, n, d);
        let q = g.vec_gaussian(d);
        for compression in [Compression::Lvq8, Compression::Lvq4, Compression::Lvq4x8] {
            let store = make_store(&rows, compression);
            let pq = store.prepare(&q, Similarity::InnerProduct);
            for i in 0..n as u32 {
                let s = store.score(&pq, i);
                let want = dot(&q, &store.decode(i));
                // lvq4x8 primary score uses only the first level
                let tol = if compression == Compression::Lvq4x8 {
                    let dec1_err: f32 = 0.4 * q.iter().map(|x| x.abs()).sum::<f32>();
                    dec1_err.max(0.5)
                } else {
                    1e-2 * (1.0 + want.abs())
                };
                prop_assert!(
                    (s - want).abs() <= tol,
                    "{compression:?} id {i}: score {s} vs decode-dot {want}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_projection_is_row_orthonormal_and_contracting() {
    check("projection-orthonormal", Config::default(), |g| {
        let dd = g.usize_in(8, 48);
        let d = g.usize_in(2, dd.min(16));
        let n = g.usize_in(30, 120);
        let rows = rows_from(g, n, dd);
        let mut backends = leanvec::leanvec::model::TrainBackends::default();
        let m = leanvec::leanvec::model::train_projection(
            ProjectionKind::Id,
            &rows,
            None,
            d,
            &mut backends,
            g.usize_in(0, 1000) as u64,
        );
        prop_assert!(
            m.a.row_orthonormality_defect() < 1e-3,
            "defect {}",
            m.a.row_orthonormality_defect()
        );
        // orthonormal projection never increases norms
        for r in rows.iter().take(10) {
            let p = m.project_database_vector(r);
            let n_in = dot(r, r).sqrt();
            let n_out = dot(&p, &p).sqrt();
            prop_assert!(n_out <= n_in * 1.001, "{n_out} > {n_in}");
        }
        Ok(())
    });
}

#[test]
fn prop_graph_degrees_bounded_no_self_loops() {
    check(
        "graph-invariants",
        Config {
            cases: 12,
            ..Config::default()
        },
        |g| {
            let n = g.usize_in(50, 250);
            let d = g.usize_in(4, 16);
            let rows = rows_from(g, n, d);
            let store = make_store(&rows, Compression::F32);
            let mut gp = GraphParams::for_similarity(Similarity::L2);
            gp.max_degree = g.usize_in(4, 20);
            gp.build_window = gp.max_degree * 2;
            let graph =
                leanvec::graph::vamana::VamanaBuilder::new(gp, Similarity::L2).build(store.as_ref());
            for i in 0..n as u32 {
                let nbrs = graph.adj.neighbors(i);
                prop_assert!(nbrs.len() <= gp.max_degree, "degree overflow");
                prop_assert!(!nbrs.contains(&i), "self loop at {i}");
                let set: std::collections::HashSet<_> = nbrs.iter().collect();
                prop_assert!(set.len() == nbrs.len(), "duplicate edge at {i}");
                prop_assert!(nbrs.iter().all(|&x| (x as usize) < n), "dangling edge");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_search_results_sorted_and_unique() {
    check(
        "search-results",
        Config {
            cases: 16,
            ..Config::default()
        },
        |g| {
            let n = g.usize_in(100, 400);
            let d = g.usize_in(4, 24);
            let rows = rows_from(g, n, d);
            let index = IndexBuilder::new()
                .projection(ProjectionKind::None)
                .primary(Compression::Lvq8)
                .build(&rows, None, Similarity::InnerProduct);
            let q = g.vec_gaussian(d);
            let k = g.usize_in(1, 20);
            let r = index.search_one(&Query::new(&q).k(k).window(k * 3));
            let (ids, scores) = (r.ids, r.scores);
            prop_assert!(ids.len() <= k, "too many results");
            let set: std::collections::HashSet<_> = ids.iter().collect();
            prop_assert!(set.len() == ids.len(), "duplicate result ids");
            for w in scores.windows(2) {
                prop_assert!(w[0] >= w[1], "scores not sorted: {scores:?}");
            }
            prop_assert!(ids.iter().all(|&i| (i as usize) < n), "id out of range");
            Ok(())
        },
    );
}

#[test]
fn prop_coordinator_serves_every_request_exactly_once() {
    check(
        "coordinator-exactly-once",
        Config {
            cases: 8,
            ..Config::default()
        },
        |g| {
            let n = g.usize_in(80, 200);
            let d = 8;
            let rows = rows_from(g, n, d);
            let index = std::sync::Arc::new(
                IndexBuilder::new()
                    .projection(ProjectionKind::None)
                    .build(&rows, None, Similarity::InnerProduct),
            );
            let n_req = g.usize_in(1, 60);
            let queries: Vec<Vec<f32>> = (0..n_req).map(|_| g.vec_gaussian(d)).collect();
            let cfg = leanvec::coordinator::EngineConfig {
                workers: g.usize_in(1, 3),
                ..Default::default()
            };
            let (responses, _) =
                leanvec::coordinator::Engine::run_workload(index, cfg, &queries, 5, None);
            prop_assert!(responses.len() == n_req, "lost/duplicated responses");
            let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            for (i, id) in ids.iter().enumerate() {
                prop_assert!(*id == i as u64, "response ids not a permutation");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_f16_roundtrip_monotone() {
    check("f16-monotone", Config::default(), |g| {
        // f16 encoding preserves ordering of magnitudes
        let a = g.f32_in(-100.0, 100.0);
        let b = g.f32_in(-100.0, 100.0);
        let (ra, rb) = (
            leanvec::util::f16::f16_to_f32(leanvec::util::f16::f32_to_f16(a)),
            leanvec::util::f16::f16_to_f32(leanvec::util::f16::f32_to_f16(b)),
        );
        if a < b {
            prop_assert!(ra <= rb, "ordering broken: {a} < {b} but {ra} > {rb}");
        }
        Ok(())
    });
}

#[test]
fn prop_recall_metric_bounds() {
    check("recall-bounds", Config::default(), |g| {
        let k = g.usize_in(1, 10);
        let q = g.usize_in(1, 10);
        let truth: Vec<Vec<u32>> = (0..q)
            .map(|_| (0..k).map(|_| g.usize_in(0, 1000) as u32).collect())
            .collect();
        let got: Vec<Vec<u32>> = (0..q)
            .map(|_| (0..k).map(|_| g.usize_in(0, 1000) as u32).collect())
            .collect();
        let r = leanvec::data::gt::recall_at_k(&got, &truth, k);
        prop_assert!((0.0..=1.0).contains(&r), "recall out of bounds: {r}");
        let perfect = leanvec::data::gt::recall_at_k(&truth, &truth, k);
        prop_assert!(perfect >= 0.999, "self-recall {perfect}");
        Ok(())
    });
}
