//! Deep-fsck battery: every `check_invariants` checker must pass on
//! healthy structures and detect hand-planted corruption — out-of-range
//! neighbors, self-loops, degree overflow, bad medoids, non-positive
//! LVQ scales, id-map duplicates, shard routing-seed mismatches — with
//! stable violation codes and WITHOUT panicking. Also the lint
//! self-test: each `leanvec-lint` rule fires on a bad fixture and
//! stays quiet on the corrected one.

use leanvec::analysis::{scan_file, Allowlist, Rule};
use leanvec::config::{GraphParams, ProjectionKind, Similarity};
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::LeanVecIndex;
use leanvec::mutate::LiveIndex;
use leanvec::quant::{Lvq4x8Store, LvqStore, ScoreStore};
use leanvec::shard::{shard_of, ShardSpec, ShardedIndex, DEFAULT_HASH_SEED};
use leanvec::util::invariants::Violation;
use leanvec::util::rng::Rng;

fn clustered_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let k = 5;
    let centers: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.gaussian_f32() * 4.0).collect())
        .collect();
    (0..n)
        .map(|i| {
            let c = &centers[i % k];
            c.iter().map(|&x| x + rng.gaussian_f32() * 0.3).collect()
        })
        .collect()
}

fn build(rows: &[Vec<f32>], target_dim: usize) -> LeanVecIndex {
    let mut gp = GraphParams::for_similarity(Similarity::L2);
    gp.max_degree = 24;
    gp.build_window = 60;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(target_dim)
        .graph_params(gp)
        .build(rows, None, Similarity::L2)
}

// ---------------------------------------------------------------- frozen

#[test]
fn clean_frozen_index_passes_fsck() {
    let rows = clustered_rows(300, 16, 1);
    let index = build(&rows, 8);
    let report = index.check_invariants();
    assert!(report.is_clean(), "fresh index must fsck clean:\n{report}");
    assert!(
        !report.checked.is_empty(),
        "clean report still names what it checked"
    );
    // the report renders without panicking in both states
    let txt = format!("{report}");
    assert!(txt.contains("fsck: clean"), "got: {txt}");
}

#[test]
fn graph_corruptions_detected_without_panicking() {
    let rows = clustered_rows(300, 16, 2);
    let n = rows.len() as u32;
    let mut index = build(&rows, 8);
    assert!(index.check_invariants().is_clean());

    // (1) neighbor id past the end of the store
    index.graph.adj.set_neighbors(0, &[n + 100]);
    let r = index.check_invariants();
    assert!(r.has_code("neighbor-out-of-range"), "{r}");

    // (2) a node naming itself as a neighbor
    index.graph.adj.set_neighbors(1, &[1]);
    let r = index.check_invariants();
    assert!(r.has_code("self-loop"), "{r}");

    // (3) stored degree larger than max_degree (slab len forged): the
    // checker must flag it WITHOUT forming the oversized slice
    index.graph.adj.corrupt_degree_for_fsck(2, 200);
    let r = index.check_invariants();
    assert!(r.has_code("degree-overflow"), "{r}");

    // (4) medoid outside the store
    index.graph.medoid = n + 7;
    let r = index.check_invariants();
    assert!(r.has_code("medoid-out-of-range"), "{r}");

    // all four coexist in one typed report
    for code in [
        "neighbor-out-of-range",
        "self-loop",
        "degree-overflow",
        "medoid-out-of-range",
    ] {
        assert!(r.has_code(code), "missing {code} in:\n{r}");
    }
}

// ----------------------------------------------------------------- quant

#[test]
fn lvq_scale_corruption_detected() {
    let rows = clustered_rows(64, 12, 3);
    let mut store = LvqStore::new(&rows, 8);
    let mut out: Vec<Violation> = Vec::new();
    store.check_invariants(&mut out);
    assert!(out.is_empty(), "fresh LVQ store must be clean: {out:?}");

    // negative per-vector scale: decoded values become garbage, so the
    // checker must call it out as a typed violation
    store.corrupt_delta_for_fsck(3, -0.5);
    let mut out: Vec<Violation> = Vec::new();
    store.check_invariants(&mut out);
    assert!(
        out.iter().any(|v| v.code == "scale-not-positive"),
        "negative delta not flagged: {out:?}"
    );

    // NaN scale is the same class of corruption
    store.corrupt_delta_for_fsck(5, f32::NAN);
    let mut out: Vec<Violation> = Vec::new();
    store.check_invariants(&mut out);
    assert!(out.iter().any(|v| v.code == "scale-not-positive"));
}

#[test]
fn lvq4x8_clean_store_passes() {
    let rows = clustered_rows(64, 12, 4);
    let store = Lvq4x8Store::new(&rows);
    let mut out: Vec<Violation> = Vec::new();
    store.check_invariants(&mut out);
    assert!(out.is_empty(), "fresh two-level store must be clean: {out:?}");
}

// ------------------------------------------------------------------ live

#[test]
fn live_index_clean_after_churn_then_idmap_corruption_detected() {
    let dim = 16;
    let rows = clustered_rows(400, dim, 5);
    let live = LiveIndex::from_index(build(&rows, 8));
    // churn a little so tombstones + insert log are exercised
    for id in 0..20u32 {
        live.delete(id).unwrap();
    }
    let mut rng = Rng::new(7);
    for id in 400..420u32 {
        let v: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        live.insert(id, &v).unwrap();
    }
    let report = live.check_invariants();
    assert!(
        report.is_clean(),
        "live index after churn must be clean:\n{report}"
    );

    // point an external id at a slot that does not exist: the ext<->int
    // map is no longer a bijection onto live slots
    live.corrupt_idmap_for_fsck(100, 1_000_000);
    let report = live.check_invariants();
    assert!(report.has_code("idmap-not-bijective"), "{report}");
}

#[test]
fn live_idmap_duplicate_slot_detected() {
    let rows = clustered_rows(200, 16, 6);
    let live = LiveIndex::from_index(build(&rows, 8));
    // two external ids mapped to the same internal slot: ext 10 now
    // also claims ext 11's slot, so ext_of[slot] cannot agree with both
    let slot_of_11 = 11u32; // from_index maps ext id i to slot i
    live.corrupt_idmap_for_fsck(10, slot_of_11);
    let report = live.check_invariants();
    assert!(report.has_code("idmap-not-bijective"), "{report}");
}

// --------------------------------------------------------------- sharded

#[test]
fn sharded_clean_then_routing_corruptions_detected() {
    let rows = clustered_rows(600, 16, 8);
    let spec = ShardSpec::new(3);
    let sharded = ShardedIndex::build_live(&rows, None, Similarity::L2, spec, 1, |b| {
        let mut gp = GraphParams::for_similarity(Similarity::L2);
        gp.max_degree = 24;
        gp.build_window = 60;
        b.projection(ProjectionKind::Id)
            .target_dim(8)
            .graph_params(gp)
    });
    let report = sharded.check_invariants();
    assert!(
        report.is_clean(),
        "fresh sharded index must be clean:\n{report}"
    );

    // (1) same shards, wrong routing seed in the spec: ids now hash
    // somewhere else, so ownership disagrees with routing
    let shards = sharded.live_shards().to_vec();
    let bad = ShardedIndex::from_live_shards(
        shards.clone(),
        ShardSpec {
            shards: 3,
            hash_seed: DEFAULT_HASH_SEED ^ 0xdead_beef,
        },
    );
    let report = bad.check_invariants();
    assert!(report.has_code("routing-seed"), "{report}");

    // sanity: at least one id really does route differently under the
    // corrupted seed, so the assertion above cannot pass vacuously
    let moved = (0..600u32).any(|id| {
        shard_of(id, DEFAULT_HASH_SEED, 3) != shard_of(id, DEFAULT_HASH_SEED ^ 0xdead_beef, 3)
    });
    assert!(moved);

    // (2) the same shard mounted twice: external ids owned by two
    // shards at once
    let dup = ShardedIndex::from_live_shards(
        vec![shards[0].clone(), shards[0].clone()],
        ShardSpec {
            shards: 2,
            hash_seed: DEFAULT_HASH_SEED,
        },
    );
    let report = dup.check_invariants();
    assert!(report.has_code("ext-id-overlap"), "{report}");
}

// ------------------------------------------------------- lint self-tests

fn codes(rel: &str, src: &str) -> Vec<&'static str> {
    scan_file(rel, src).iter().map(|d| d.rule.name()).collect()
}

#[test]
fn lint_unsafe_needs_safety_comment() {
    let bad = "pub fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
    assert_eq!(codes("simd/x86.rs", bad), vec!["unsafe-safety-comment"]);

    let good = "pub fn f(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid for reads.\n    unsafe { *p }\n}\n";
    assert!(codes("simd/x86.rs", good).is_empty());
}

#[test]
fn lint_serve_path_panic_scoping() {
    let bad = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    // fires on serve-path modules...
    assert_eq!(codes("graph/beam.rs", bad), vec!["serve-path-panic"]);
    assert_eq!(codes("util/mmap.rs", bad), vec!["serve-path-panic"]);
    // ...but not off the serve path, and not inside #[cfg(test)]
    assert!(codes("experiments/harness.rs", bad).is_empty());
    let in_test = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        Some(1u32).unwrap();\n    }\n}\n";
    assert!(codes("graph/beam.rs", in_test).is_empty());
    // inline waiver with a reason silences one site
    let waived = "fn f(x: Option<u32>) -> u32 {\n    // lint:allow(serve-path-panic): construction-time, not per-query\n    x.unwrap()\n}\n";
    assert!(codes("graph/beam.rs", waived).is_empty());
}

#[test]
fn lint_partial_cmp_on_serve_path() {
    let bad = "fn f(a: f32, b: f32) -> bool {\n    a.partial_cmp(&b).is_some()\n}\n";
    assert_eq!(
        codes("index/leanvec_index.rs", bad),
        vec!["serve-path-partial-cmp"]
    );
    let good = "fn f(a: f32, b: f32) -> std::cmp::Ordering {\n    a.total_cmp(&b)\n}\n";
    assert!(codes("index/leanvec_index.rs", good).is_empty());
}

#[test]
fn lint_relaxed_needs_ordering_comment() {
    let bad = "fn f(c: &std::sync::atomic::AtomicUsize) -> usize {\n    c.load(std::sync::atomic::Ordering::Relaxed)\n}\n";
    assert_eq!(
        codes("util/threadpool.rs", bad),
        vec!["relaxed-ordering-comment"]
    );
    let good = "fn f(c: &std::sync::atomic::AtomicUsize) -> usize {\n    // ORDERING: monotonic stat counter, no cross-thread data depends on it.\n    c.load(std::sync::atomic::Ordering::Relaxed)\n}\n";
    assert!(codes("util/threadpool.rs", good).is_empty());
}

#[test]
fn lint_instant_banned_in_kernels() {
    let bad = "pub fn dot(a: &[f32]) -> f32 {\n    let _t = std::time::Instant::now();\n    a.iter().sum()\n}\n";
    assert!(codes("simd/mod.rs", bad).contains(&"instant-in-kernel"));
    // the same code is fine outside the kernel layer
    assert!(codes("util/timer.rs", bad).is_empty());
}

#[test]
fn lint_println_outside_cli() {
    let bad = "fn f() {\n    println!(\"debug\");\n}\n";
    assert_eq!(codes("graph/beam.rs", bad), vec!["println-outside-cli"]);
    assert!(codes("main.rs", bad).is_empty());
    assert!(codes("bin/lint.rs", bad).is_empty());
    // stderr is always fine
    let err = "fn f() {\n    eprintln!(\"debug\");\n}\n";
    assert!(codes("graph/beam.rs", err).is_empty());
}

#[test]
fn lint_unbounded_wait_on_request_loop() {
    let bad = "fn f(rx: &std::sync::mpsc::Receiver<u32>) {\n    let _ = rx.recv();\n}\n";
    assert_eq!(
        codes("coordinator/engine.rs", bad),
        vec!["serve-path-unbounded-wait"]
    );
    // a DEADLINE: justification on or immediately above the line quiets it
    let justified = "fn f(rx: &std::sync::mpsc::Receiver<u32>) {\n    // DEADLINE: idle state; shutdown closes the sender.\n    let _ = rx.recv();\n}\n";
    assert!(codes("coordinator/engine.rs", justified).is_empty());
    // timeout-aware forms need no annotation
    let timed = "fn f(rx: &std::sync::mpsc::Receiver<u32>, d: std::time::Duration) {\n    let _ = rx.recv_timeout(d);\n}\n";
    assert!(codes("coordinator/engine.rs", timed).is_empty());
    // Path::join takes an argument — only zero-arg thread joins match
    let path_join = "fn f(p: &std::path::Path) -> std::path::PathBuf {\n    p.join(\"manifest.json\")\n}\n";
    assert!(codes("shard/manifest.rs", path_join).is_empty());
    // the rule polices the request loop only, not background modules
    assert!(codes("util/threadpool.rs", bad).is_empty());
}

#[test]
fn lint_allowlist_parses_and_matches() {
    let allow = Allowlist::parse(
        "# comment line\n\nprintln-outside-cli experiments/harness.rs prints tables by design\n",
    )
    .unwrap();
    assert_eq!(allow.len(), 1);
    let diags = scan_file("experiments/harness.rs", "fn f() { println!(\"x\"); }\n");
    // experiments/ is not CLI, so the rule fires — and the allowlist
    // waives exactly that (path, rule) pair
    assert!(!diags.is_empty());
    assert!(diags.iter().all(|d| allow.allows(d)));

    let other = scan_file("graph/beam.rs", "fn f() { println!(\"x\"); }\n");
    assert!(other.iter().all(|d| !allow.allows(d)));

    // unknown rule names are a parse error, not a silent no-op
    assert!(Allowlist::parse("no-such-rule foo.rs\n").is_err());
    assert!(Rule::from_name("serve-path-panic").is_some());
}
