//! Mmap serving conformance: for every store kind and similarity, an
//! index served off a memory map (`load_mmap`) must answer
//! bit-identically — ids, score bits, `QueryStats` — to the same
//! snapshot decoded into owned memory (`load`), including filtered
//! queries and the batch path. Also covers the resident-codes policy,
//! shard-directory mmap round trips with per-shard error naming, and
//! the `LEANVEC_FORCE_MMAP` escape hatch.

use leanvec::config::{Compression, GraphParams, ProjectionKind, Similarity};
use leanvec::graph::beam::SearchCtx;
use leanvec::index::builder::IndexBuilder;
use leanvec::index::leanvec_index::LeanVecIndex;
use leanvec::index::persist::{SnapshotError, SnapshotMeta};
use leanvec::index::query::{Query, VectorIndex};
use leanvec::index::MmapPolicy;
use leanvec::shard::{ShardSpec, ShardedIndex};
use leanvec::util::rng::Rng;
use std::path::PathBuf;

fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..d).map(|_| rng.gaussian_f32()).collect())
        .collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("leanvec-mmap-{}-{name}", std::process::id()))
}

fn build(primary: Compression, sim: Similarity, seed: u64) -> LeanVecIndex {
    let x = rows(300, 16, seed);
    let q = rows(60, 16, seed + 1);
    let mut gp = GraphParams::for_similarity(sim);
    gp.max_degree = 16;
    gp.build_window = 40;
    IndexBuilder::new()
        .projection(ProjectionKind::Id)
        .target_dim(6)
        .primary(primary)
        .secondary(Compression::F16)
        .graph_params(gp)
        .seed(91)
        .build(&x, Some(&q), sim)
}

/// `a` and `b` must be indistinguishable to a caller: same ids, same
/// score bits, same `QueryStats`, on plain, filtered, and batch
/// searches.
fn assert_serving_identical(a: &LeanVecIndex, b: &LeanVecIndex, seed: u64) {
    assert_eq!(a.len(), b.len());
    let bits = |s: &[f32]| s.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
    let mut rng = Rng::new(seed);
    let dd = a.model.input_dim();
    let mut ctx_a = SearchCtx::new(a.len());
    let mut ctx_b = SearchCtx::new(b.len());
    let queries: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..dd).map(|_| rng.gaussian_f32()).collect())
        .collect();
    let keep_even = |id: u32| id % 2 == 0;
    for v in &queries {
        for filtered in [false, true] {
            let mut q = Query::new(v).k(10).window(30);
            if filtered {
                q = q.filter(&keep_even);
            }
            let ra = a.search(&mut ctx_a, &q);
            let rb = b.search(&mut ctx_b, &q);
            assert_eq!(ra.ids, rb.ids, "ids diverged (filtered={filtered})");
            assert_eq!(bits(&ra.scores), bits(&rb.scores), "score bits diverged");
            assert_eq!(ra.stats, rb.stats, "QueryStats diverged");
            if filtered {
                assert!(ra.ids.iter().all(|&id| keep_even(id)));
            }
        }
    }
    // the batch path (thread fan-out) over the same queries
    let reqs: Vec<Query> = queries.iter().map(|v| Query::new(v).k(10).window(30)).collect();
    for threads in [1, 3] {
        let ba = a.search_batch(&reqs, threads);
        let bb = b.search_batch(&reqs, threads);
        for (ra, rb) in ba.iter().zip(&bb) {
            assert_eq!(ra.ids, rb.ids, "batch ids diverged at threads={threads}");
            assert_eq!(bits(&ra.scores), bits(&rb.scores));
            assert_eq!(ra.stats, rb.stats);
        }
    }
}

/// Every primary store kind × both similarities: owned and mapped
/// serving are bit-identical.
#[test]
fn all_store_kinds_serve_identically_owned_vs_mapped() {
    let kinds = [
        Compression::F32,
        Compression::F16,
        Compression::Lvq4,
        Compression::Lvq8,
        Compression::Lvq4x8,
    ];
    let sims = [Similarity::InnerProduct, Similarity::L2];
    for (i, &primary) in kinds.iter().enumerate() {
        for (j, &sim) in sims.iter().enumerate() {
            let seed = 100 + (i * 2 + j) as u64;
            let built = build(primary, sim, seed);
            let path = tmp(&format!("conf-{i}-{j}.leanvec"));
            built.save(&path, &SnapshotMeta::default()).unwrap();
            let (owned, _) = LeanVecIndex::load(&path).unwrap();
            let (mapped, _) = LeanVecIndex::load_mmap(&path).unwrap();
            assert!(mapped.is_mapped(), "{primary:?}/{sim:?} not mapped");
            // the deep-fsck checkers must pass over every store kind,
            // owned and mapped alike — same code path as `repro fsck`
            let fo = owned.check_invariants();
            assert!(fo.is_clean(), "{primary:?}/{sim:?} owned fsck:\n{fo}");
            let fm = mapped.check_invariants();
            assert!(fm.is_clean(), "{primary:?}/{sim:?} mapped fsck:\n{fm}");
            assert_serving_identical(&built, &owned, seed + 1000);
            assert_serving_identical(&owned, &mapped, seed + 1000);
            std::fs::remove_file(&path).ok();
        }
    }
}

/// `MmapPolicy::resident_codes()` (hot codes decoded to RAM, rerank
/// store left on the map) serves the same bits as the all-mapped
/// default.
#[test]
fn resident_codes_policy_matches_fully_mapped() {
    let built = build(Compression::Lvq4x8, Similarity::InnerProduct, 31);
    let path = tmp("policy.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();
    let (mapped, _) = LeanVecIndex::load_mmap(&path).unwrap();
    let (resident, _) = LeanVecIndex::load_mmap_with(&path, MmapPolicy::resident_codes()).unwrap();
    assert!(resident.is_mapped(), "rerank tier still maps the file");
    assert_serving_identical(&mapped, &resident, 4100);
    std::fs::remove_file(&path).ok();
}

fn sharded_fixture(seed: u64) -> (ShardedIndex, Vec<Vec<f32>>) {
    let x = rows(700, 24, seed);
    let learn = rows(80, 24, seed + 1);
    let configure = |b: IndexBuilder| {
        let mut gp = GraphParams::for_similarity(Similarity::InnerProduct);
        gp.max_degree = 16;
        gp.build_window = 40;
        b.projection(ProjectionKind::Id)
            .target_dim(8)
            .primary(Compression::Lvq8)
            .secondary(Compression::F16)
            .graph_params(gp)
    };
    let ix = ShardedIndex::build(
        &x,
        Some(&learn),
        Similarity::InnerProduct,
        ShardSpec::new(3),
        1,
        configure,
    );
    let queries = rows(20, 24, seed + 2);
    (ix, queries)
}

/// A shard directory loaded with an mmap policy serves scatter-gather
/// results identical to the same directory decoded into owned memory.
#[test]
fn shard_dir_mmap_round_trip_serves_identically() {
    let (ix, queries) = sharded_fixture(41);
    let dir = tmp("shard-dir");
    let _ = std::fs::remove_dir_all(&dir);
    ix.save_dir(&dir, &SnapshotMeta::default()).expect("save_dir");
    let (owned, _) = ShardedIndex::load_dir_with(&dir, None).expect("owned load");
    let (mapped, _) =
        ShardedIndex::load_dir_with(&dir, Some(MmapPolicy::default())).expect("mmap load");
    assert_eq!(VectorIndex::len(&mapped), VectorIndex::len(&ix));
    // a round-tripped shard directory must fsck clean in both modes
    let fo = owned.check_invariants();
    assert!(fo.is_clean(), "owned shard dir fsck:\n{fo}");
    let fm = mapped.check_invariants();
    assert!(fm.is_clean(), "mapped shard dir fsck:\n{fm}");
    for v in &queries {
        let q = Query::new(v).k(10).window(40);
        let a = owned.search_scatter(&owned.model().project_query(v), &q);
        let b = mapped.search_scatter(&mapped.model().project_query(v), &q);
        assert_eq!(a, b, "mapped shard set diverged from owned");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shard that parses cleanly but disagrees with the manifest's row
/// count fails with `SnapshotError::Shard` naming the offending file —
/// under both owned and mapped loads.
#[test]
fn shard_load_failure_names_the_shard_file() {
    let (ix, _) = sharded_fixture(43);
    let dir = tmp("shard-err");
    let _ = std::fs::remove_dir_all(&dir);
    ix.save_dir(&dir, &SnapshotMeta::default()).expect("save_dir");
    // corrupt entry 0's row count in the manifest and re-seal the
    // trailer CRC, so the per-file CRC gate passes and the failure
    // surfaces from the shard loader itself.
    // layout: magic(8) version(4) kind(1) count(4) seed(8),
    // entry = name_len(4) + "shard-000.leanvec"(17) + crc(4) + rows(8)
    let mpath = dir.join(leanvec::shard::MANIFEST_NAME);
    let mut m = std::fs::read(&mpath).unwrap();
    let rows_at = 8 + 4 + 1 + 4 + 8 + 4 + "shard-000.leanvec".len() + 4;
    m[rows_at..rows_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
    let body_len = m.len() - 4;
    let crc = leanvec::data::io::crc32(&m[..body_len]);
    m[body_len..].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(&mpath, &m).unwrap();
    for mmap in [None, Some(MmapPolicy::default())] {
        let err = ShardedIndex::load_dir_with(&dir, mmap)
            .err()
            .expect("row-count skew must fail the load");
        match err {
            SnapshotError::Shard { file, source } => {
                assert_eq!(file, "shard-000.leanvec");
                let _ = format!("{source}");
            }
            other => panic!("expected Shard error, got {other:?}"),
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// `LEANVEC_FORCE_MMAP=1` reroutes the plain owned loader onto the
/// mapped path (same contract as `LEANVEC_FORCE_SCALAR` for kernels);
/// empty or "0" restores the default. Results stay bit-identical
/// either way.
#[test]
fn force_mmap_env_reroutes_plain_load() {
    let built = build(Compression::Lvq8, Similarity::InnerProduct, 53);
    let path = tmp("force.leanvec");
    built.save(&path, &SnapshotMeta::default()).unwrap();

    std::env::set_var("LEANVEC_FORCE_MMAP", "1");
    let (forced, _) = LeanVecIndex::load(&path).unwrap();
    assert!(forced.is_mapped(), "FORCE_MMAP=1 must map the plain load");
    assert_serving_identical(&built, &forced, 6200);

    std::env::set_var("LEANVEC_FORCE_MMAP", "0");
    let (plain, _) = LeanVecIndex::load(&path).unwrap();
    assert!(!plain.is_mapped(), "FORCE_MMAP=0 must decode owned");
    assert_serving_identical(&built, &plain, 6200);

    std::env::remove_var("LEANVEC_FORCE_MMAP");
    std::fs::remove_file(&path).ok();
}
