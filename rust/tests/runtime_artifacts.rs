//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-check numerics against the native implementations. These tests
//! skip (pass trivially) when `make artifacts` has not been run.

use leanvec::leanvec::eigsearch::TopdBackend;
use leanvec::leanvec::fw::{FwStepper, NativeStepper};
use leanvec::linalg::Matrix;
use leanvec::runtime::client::{lit_from_f32s, lit_from_matrix, lit_from_u8, matrix_from_lit};
use leanvec::runtime::{default_artifacts_dir, PjrtRuntime};
use leanvec::util::rng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    PjrtRuntime::open(&default_artifacts_dir()).ok()
}

fn psd(dd: usize, n: usize, rng: &mut Rng) -> Matrix {
    Matrix::randn(n, dd, rng).second_moment()
}

#[test]
fn manifest_has_default_shapes() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = rt.manifest();
    for (dd, d) in [(768, 160), (512, 128), (256, 96), (200, 128)] {
        assert!(m.find("fw_step", dd, d).is_some(), "fw_step {dd}x{d}");
        assert!(m.find("fw_step_xla", dd, d).is_some(), "fw_step_xla {dd}x{d}");
        assert!(m.find("eig_topd", dd, d).is_some(), "eig_topd {dd}x{d}");
        assert!(m.find("project", dd, d).is_some(), "project {dd}x{d}");
        assert!(m.find("score_batch", dd, d).is_some(), "score {dd}x{d}");
    }
}

#[test]
fn project_artifact_matches_native_matmul() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let spec = rt.manifest().find("project", 256, 96).unwrap().clone();
    let b = spec.batch.unwrap();
    let mut rng = Rng::new(1);
    let p = Matrix::randn(96, 256, &mut rng);
    let x = Matrix::randn(256, b, &mut rng);
    let out = rt
        .execute(
            &spec.name,
            &[lit_from_matrix(&p).unwrap(), lit_from_matrix(&x).unwrap()],
        )
        .unwrap();
    let y = matrix_from_lit(&out[0], 96, b).unwrap();
    let want = p.matmul(&x);
    assert!(y.max_abs_diff(&want) < 1e-2, "{}", y.max_abs_diff(&want));
}

#[test]
fn fw_step_artifact_matches_native_stepper() {
    let Some(rt) = leanvec::runtime::executor::open_shared(&default_artifacts_dir()).ok() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Rng::new(2);
    let (dd, d) = (256usize, 96usize);
    let kq = psd(dd, 400, &mut rng);
    let kx = psd(dd, 400, &mut rng);
    let a0 = leanvec::linalg::qr::random_orthonormal(d, dd, &mut rng);
    let b0 = leanvec::linalg::qr::random_orthonormal(d, dd, &mut rng);

    let mut pjrt = leanvec::runtime::PjrtFwStepper::new(rt);
    let (pa, pb, pl) = pjrt.step(&a0, &b0, &kq, &kx, 0.5);
    assert!(pjrt.stats.pjrt >= 1, "must have dispatched via pjrt");

    let (na, nb, nl) = NativeStepper.step(&a0, &b0, &kq, &kx, 0.5);
    assert!(pa.max_abs_diff(&na) < 2e-2, "A diff {}", pa.max_abs_diff(&na));
    assert!(pb.max_abs_diff(&nb) < 2e-2, "B diff {}", pb.max_abs_diff(&nb));
    let rel = (pl - nl).abs() / nl.abs().max(1e-12);
    assert!(rel < 1e-2, "loss {pl} vs {nl}");
}

#[test]
fn eig_topd_artifact_spans_top_subspace() {
    let Some(rt) = leanvec::runtime::executor::open_shared(&default_artifacts_dir()).ok() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut rng = Rng::new(3);
    // d * 3 <= D so the PJRT subspace-iteration artifact is eligible
    // (at aggressive d/D ratios PjrtTopd falls back to native Jacobi)
    let dd = 512usize;
    let d = 128usize;
    // decaying-spectrum PSD so the top subspace is well defined
    let mut x = Matrix::randn(900, dd, &mut rng);
    for row in x.data.chunks_mut(dd) {
        for (c, v) in row.iter_mut().enumerate() {
            *v *= 1.0 / (1.0 + c as f32 * 0.15);
        }
    }
    let k = x.second_moment();
    let mut pjrt = leanvec::runtime::PjrtTopd::new(rt);
    let p = pjrt.topd(&k, d);
    assert!(pjrt.stats.pjrt >= 1);
    assert!(p.row_orthonormality_defect() < 2e-2);
    // captured energy close to the exact top-d total
    let exact = leanvec::linalg::top_eigvecs(&k, d);
    let captured = p.matmul(&k).matmul_nt(&p).trace();
    let best = exact.matmul(&k).matmul_nt(&exact).trace();
    assert!(captured >= 0.98 * best, "{captured} vs {best}");
}

#[test]
fn score_artifact_matches_native_lvq_scores() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let spec = match rt.manifest().find("score_batch", 256, 96) {
        Some(s) => s.clone(),
        None => return,
    };
    let n = spec.batch.unwrap();
    let d = 96usize;
    let mut rng = Rng::new(4);
    let codes: Vec<u8> = (0..n * d).map(|_| rng.below(256) as u8).collect();
    let delta: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.01 + 1e-4).collect();
    let lo: Vec<f32> = (0..n).map(|_| rng.gaussian_f32() * 0.01).collect();
    let q: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
    let qstats = [q.iter().sum::<f32>(), 0.25f32];
    let q_col = Matrix::from_vec(d, 1, q.clone());
    let out = rt
        .execute(
            &spec.name,
            &[
                lit_from_u8(n, d, &codes).unwrap(),
                lit_from_f32s(&delta).unwrap(),
                lit_from_f32s(&lo).unwrap(),
                lit_from_matrix(&q_col).unwrap(),
                lit_from_f32s(&qstats).unwrap(),
            ],
        )
        .unwrap();
    let scores: Vec<f32> = out[0].to_vec().unwrap();
    assert_eq!(scores.len(), n);
    for i in 0..n {
        let code_dot: f32 = codes[i * d..(i + 1) * d]
            .iter()
            .zip(q.iter())
            .map(|(&c, &qv)| c as f32 * qv)
            .sum();
        let want = delta[i] * code_dot + lo[i] * qstats[0] + qstats[1];
        assert!(
            (scores[i] - want).abs() < 1e-2 * (1.0 + want.abs()),
            "i={i}: {} vs {want}",
            scores[i]
        );
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let spec = rt.manifest().find("project", 200, 128).unwrap().clone();
    let b = spec.batch.unwrap();
    let mut rng = Rng::new(5);
    let p = Matrix::randn(128, 200, &mut rng);
    let x = Matrix::randn(200, b, &mut rng);
    let t0 = std::time::Instant::now();
    rt.execute(
        &spec.name,
        &[lit_from_matrix(&p).unwrap(), lit_from_matrix(&x).unwrap()],
    )
    .unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        rt.execute(
            &spec.name,
            &[lit_from_matrix(&p).unwrap(), lit_from_matrix(&x).unwrap()],
        )
        .unwrap();
    }
    let warm = t1.elapsed() / 3;
    assert!(warm < first, "warm {warm:?} should be below cold {first:?}");
    assert_eq!(rt.dispatch_counts[&spec.name], 4);
}

#[test]
fn unknown_artifact_is_a_clean_error() {
    let Some(mut rt) = runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert!(rt.execute("definitely_not_there", &[]).is_err());
}
